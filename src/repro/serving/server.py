"""GraphServer — asyncio request queue, dynamic micro-batching, admission.

The serving loop that feeds :meth:`GraphSession.run_batch`:

* **Queue**: ``submit`` places a :class:`~repro.serving.api.QueryRequest`
  into a compatibility bucket keyed by ``(graph, plan.batch_key())`` —
  reusing :class:`~repro.core.plan.ExecutionPlan` hashability — and
  returns a future. The queue is bounded (``max_queue``): beyond it,
  ``queue_policy="reject"`` raises :class:`AdmissionError` (shed load),
  ``"wait"`` backpressures the submitter until a slot frees.
* **Micro-batcher**: a dispatcher task drains the *largest* bucket first
  (maximizing fused occupancy, like the seed LLM batcher), waiting up to
  ``max_wait_ms`` for a partially filled bucket to grow before cutting a
  batch of ≤ ``max_batch`` requests. Each batch is one
  ``session.run_batch(plans)`` call — K point queries ride one streamed
  pass, edge bytes paid once (``run_batch`` itself re-verifies aux-level
  fusability and falls back to sequential runs if e.g. two PageRank
  plans froze different damping aux; results are identical either way).
* **Admission control**: before a batch runs, its in-flight byte estimate
  (:func:`estimate_inflight_parts` — the session's three-level-budget
  resident set / packed stream plan for device topology, plus
  ``2·n_pad·Ba·K`` attribute state) must fit ``inflight_capacity``
  alongside already-running batches, or the batch waits. The topology
  term is charged *once per graph* across concurrently admitted batches
  (the pinned tiles / stream ring are shared session staging), so
  frontier-bounded point queries on one graph don't each reserve the
  full placement and spuriously serialize. A batch larger than the whole
  capacity runs *alone* (counted in ``admission_overflows``) — capacity
  bounds concurrency; the per-run working set is already bounded by each
  session's ``memory_budget``.
* **Sessions**: graphs come from a :class:`~repro.serving.pool.
  SessionPool`; a per-graph lock serializes batches on one session
  (``GraphSession`` run state is not reentrant) while different graphs
  run concurrently, up to ``max_concurrent`` executor threads.

* **Graceful degradation**: requests may carry a ``deadline_s`` budget
  (measured from enqueue) and a ``max_retries`` transient-fault budget.
  Expired requests are shed from the queue before dispatch; a request
  that expires *mid-run* cancels its batch cooperatively at the next
  sweep boundary (``session.run`` checks a ``cancel`` callback between
  sweeps — no partial sweep is ever observable) and the surviving
  members re-run. :class:`~repro.reliability.faults.TransientFault`
  escalating out of the fetch layer's own bounded retries triggers a
  batch re-run with backoff, up to the smallest member budget. Failures
  feed the pool's per-graph circuit breaker
  (:class:`~repro.serving.pool.CircuitOpenError` sheds instantly while
  open), and a :class:`~repro.reliability.faults.StragglerWatchdog`
  flags anomalously slow batches into ``ServerStats.slow_batches``.

``serve(requests)`` is the synchronous convenience wrapper (start →
submit all → gather → drain → stop); long-running callers use
``async with GraphServer(...) as srv: await srv.submit(...)``.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses
import functools
import time
from collections import OrderedDict
from typing import Sequence

from repro.core.plan import ExecutionPlan
from repro.core.session import BatchResult, GraphSession, Meters
from repro.obs.http import TelemetryServer
from repro.obs.registry import (
    DEFAULT_LATENCY_BUCKETS,
    HistogramValue,
    REGISTRY as _REGISTRY,
)
from repro.obs.trace import TRACER as _TRACER
from repro.reliability.faults import (
    DeadlineExceeded,
    StragglerWatchdog,
    TransientFault,
)
from repro.serving.api import (
    AdmissionError,
    QueryRequest,
    QueryResult,
    RequestTiming,
    ServerStats,
    split_meters,
)
from repro.serving.pool import CircuitOpenError, SessionPool

__all__ = ["GraphServer", "estimate_inflight_bytes", "estimate_inflight_parts"]

# Process-wide end-to-end request latency (enqueue → completion); each
# GraphServer additionally owns an ungated per-server HistogramValue for
# its own p50/p95/p99 so stats are not polluted across servers.
_OBS_LATENCY = _REGISTRY.histogram(
    "repro_serving_request_latency_seconds",
    "End-to-end serving request latency (enqueue to completion)",
)


def estimate_inflight_parts(
    session: GraphSession, plan: ExecutionPlan, k: int
) -> tuple[float, float]:
    """Model ``(topology, attribute)`` bytes a K-query batch keeps in flight.

    The split matters for admission: the topology term is a property of the
    *graph placement*, shared by every batch concurrently running on the
    same session (pinned tiles and stream buffers are staged once, not per
    batch), while the attribute term is genuinely per-batch state. The
    server therefore charges topology once per graph across concurrently
    admitted batches (see :meth:`GraphServer._admit`) — without the split,
    two frontier-bounded point queries on one big host-resident graph
    would each reserve the full pinned prefix and spuriously serialize.

    Topology follows the session's resolved placement — the same
    accounting that drives ``peak_device_graph_bytes``:

    * streamed residencies ("host"/"disk"), packed execution: the
      budget-pinned tile prefix plus the ≤2-chunk double-buffer ring
      (:meth:`GraphSession.packed_stream_plan`);
    * streamed residencies, per-block execution: the pinned resident set
      plus a two-block ring of the largest streamed block
      (:meth:`GraphSession._resolve_residency` semantics);
    * "device": the whole staged topology (``m·Be``).

    Attribute state is ``2·n_pad·Ba·K`` (ping-pong copies per query).
    All quantities are model units (``e·Be`` real edges), the same units
    as ``memory_budget`` and the meters, so admission accounting composes
    with the session's own budget enforcement. Both terms are upper
    bounds: a frontier-bounded selective run streams fewer chunks, never
    more.
    """
    compiled = session.compile(plan)
    g = session.graph
    ba = plan.program.attr_bytes
    attr = 2.0 * g.n_pad * ba * k
    if compiled.residency in ("host", "disk"):
        if compiled.execution in ("packed", "packed_kernel"):
            splan = session.packed_stream_plan(compiled.choice.strategy, ba)
            topo = splan.pin_model_bytes + 2.0 * splan.max_chunk_model_bytes
        else:
            host = session.host_blocks
            be = session.Be
            topo = float(
                sum(host[key]["e"] * be for key in compiled.resident)
            )
            streamed = [
                h["e"] * be
                for key, h in host.items()
                if key not in compiled.resident
            ]
            topo += 2.0 * max(streamed, default=0)
    else:
        topo = float(g.m * session.Be)
    return topo, attr


def estimate_inflight_bytes(
    session: GraphSession, plan: ExecutionPlan, k: int
) -> float:
    """Model bytes a K-query batch of ``plan`` keeps in flight on device.

    The standalone (single-batch) estimate:
    ``sum(estimate_inflight_parts(...))``. The server's admission ledger
    uses the parts directly so same-graph batches share the topology term.
    """
    topo, attr = estimate_inflight_parts(session, plan, k)
    return attr + topo


@dataclasses.dataclass
class _Pending:
    request: QueryRequest
    graph_key: str
    future: asyncio.Future
    timing: RequestTiming
    deadline_at: float | None = None  # perf_counter deadline, None = no budget


class GraphServer:
    """Async graph-query server over a :class:`SessionPool`.

    ``telemetry_port`` (e.g. ``0`` for an ephemeral port) attaches a
    scrapeable :class:`repro.obs.TelemetryServer` for the server's
    lifetime: ``GET /metrics`` publishes a fresh :class:`ServerStats`/
    ``PoolStats`` snapshot and renders the process registry as Prometheus
    text; ``GET /healthz`` reports breaker state and queue depth (HTTP
    503 when degraded). ``None`` (default) starts no endpoint.
    """

    def __init__(
        self,
        pool: SessionPool | None = None,
        *,
        max_batch: int = 16,
        max_wait_ms: float = 2.0,
        max_queue: int = 1024,
        queue_policy: str = "reject",
        inflight_capacity: float | None = None,
        max_concurrent: int = 2,
        retry_backoff_s: float = 0.005,
        watchdog: StragglerWatchdog | None = None,
        telemetry_port: int | None = None,
        telemetry_host: str = "127.0.0.1",
    ):
        if queue_policy not in ("reject", "wait"):
            raise ValueError(
                f"queue_policy must be 'reject' or 'wait', got {queue_policy!r}"
            )
        if max_batch < 1 or max_queue < 1 or max_concurrent < 1:
            raise ValueError("max_batch, max_queue, max_concurrent must be ≥ 1")
        if retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be ≥ 0")
        self.pool = pool if pool is not None else SessionPool()
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.max_queue = max_queue
        self.queue_policy = queue_policy
        self.inflight_capacity = inflight_capacity
        self.max_concurrent = max_concurrent
        self.retry_backoff_s = retry_backoff_s
        self.watchdog = watchdog if watchdog is not None else StragglerWatchdog()
        # Buckets: compatibility key -> FIFO of pending requests. Insertion
        # order of the OrderedDict breaks largest-bucket ties (oldest wins).
        self._buckets: "OrderedDict[tuple, list[_Pending]]" = OrderedDict()
        self._pending = 0
        self._next_id = 0
        self._running = False
        # Loop-bound runtime state (created in start(), per event loop).
        self._wakeup: asyncio.Event | None = None
        self._space: asyncio.Condition | None = None
        self._admit_cv: asyncio.Condition | None = None
        self._exec_sem: asyncio.Semaphore | None = None
        self._dispatcher: asyncio.Task | None = None
        self._tasks: set[asyncio.Task] = set()
        self._locks: dict[str, asyncio.Lock] = {}
        self._executor: concurrent.futures.ThreadPoolExecutor | None = None
        # Counters (survive across start/stop cycles).
        self._inflight_bytes = 0.0
        # graph_key -> number of admitted batches currently holding that
        # graph's topology reservation. The first batch on a graph charges
        # the topology term; concurrent same-graph batches charge only
        # their attribute state (the pinned tiles / stream ring are shared
        # session staging, not per-batch allocations).
        self._graph_inflight: dict[str, int] = {}
        self._stats = ServerStats()
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._lat_queue = 0.0
        self._lat_run = 0.0
        self._lat_total = 0.0
        self._lat_max = 0.0
        # Per-server latency histogram (ungated standalone — always
        # records) backing stats().p50/p95/p99_total_s.
        self._lat_hist = HistogramValue(DEFAULT_LATENCY_BUCKETS)
        # Scrape endpoint: created here, not in start(), so /metrics and
        # /healthz survive serve() start/stop waves — CI curls counters
        # after a fault-injection wave has completed. Each scrape runs
        # publish_metrics first, so scraped serving series equal the
        # ServerStats snapshot by construction. telemetry_port=0 binds an
        # ephemeral port (read it back from server.telemetry.address).
        self.telemetry: TelemetryServer | None = None
        if telemetry_port is not None:
            self.telemetry = TelemetryServer(
                health_fn=self._health,
                on_scrape=self.publish_metrics,
                host=telemetry_host,
                port=telemetry_port,
            ).start()

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> "GraphServer":
        if self._running:
            raise RuntimeError("server already started")
        self._running = True
        self._wakeup = asyncio.Event()
        self._space = asyncio.Condition()
        self._admit_cv = asyncio.Condition()
        self._exec_sem = asyncio.Semaphore(self.max_concurrent)
        self._locks = {}
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=self.max_concurrent, thread_name_prefix="graph-serve"
        )
        self._dispatcher = asyncio.create_task(self._dispatch_loop())
        return self

    async def stop(self) -> None:
        """Drain the queue, wait for in-flight batches, stop the dispatcher."""
        if not self._running:
            return
        self._running = False
        self._wakeup.set()
        await self._dispatcher
        while self._tasks:
            await asyncio.gather(*list(self._tasks), return_exceptions=True)
        self._executor.shutdown(wait=True)
        self._dispatcher = None
        self._executor = None

    async def __aenter__(self) -> "GraphServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- submission ----------------------------------------------------------
    async def submit(self, request: QueryRequest) -> asyncio.Future:
        """Enqueue one request; resolves to a :class:`QueryResult`.

        Raises :class:`AdmissionError` immediately when the bounded queue
        is full under ``queue_policy="reject"``; awaits a slot under
        ``"wait"``.
        """
        if not self._running:
            raise RuntimeError("server is not started (use start()/serve())")
        if self._pending >= self.max_queue:
            if self.queue_policy == "reject":
                self._stats.rejected += 1
                raise AdmissionError(
                    f"queue full ({self._pending}/{self.max_queue} pending)"
                )
            async with self._space:
                await self._space.wait_for(lambda: self._pending < self.max_queue)
        graph_key = self.pool.resolve(request.graph)
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now
        pending = _Pending(
            request=request,
            graph_key=graph_key,
            future=asyncio.get_running_loop().create_future(),
            timing=RequestTiming(enqueued=now),
            deadline_at=(
                now + request.deadline_s
                if request.deadline_s is not None
                else None
            ),
        )
        key = (graph_key, request.plan.batch_key())
        self._buckets.setdefault(key, []).append(pending)
        self._pending += 1
        self._stats.submitted += 1
        self._wakeup.set()
        return pending.future

    def serve(self, requests: Sequence[QueryRequest]) -> list[QueryResult]:
        """Synchronous convenience: run a fresh event loop over the batch.

        Submits every request (so the micro-batcher sees them together),
        gathers all results, drains and stops. Raises the first submit
        rejection / execution error.
        """

        async def _run():
            async with self:
                futures = [await self.submit(r) for r in requests]
                return list(await asyncio.gather(*futures))

        return asyncio.run(_run())

    # -- stats ---------------------------------------------------------------
    def stats(self) -> ServerStats:
        s = self._stats
        done = s.completed
        window = (
            (self._t_last - self._t_first)
            if (self._t_first is not None and self._t_last is not None)
            else 0.0
        )
        return dataclasses.replace(
            s,
            queue_depth=self._pending,
            inflight_bytes=self._inflight_bytes,
            qps=(done / window) if window > 0 else 0.0,
            mean_queue_s=self._lat_queue / done if done else 0.0,
            mean_run_s=self._lat_run / done if done else 0.0,
            mean_total_s=self._lat_total / done if done else 0.0,
            max_total_s=self._lat_max,
            p50_total_s=self._lat_hist.quantile(0.50),
            p95_total_s=self._lat_hist.quantile(0.95),
            p99_total_s=self._lat_hist.quantile(0.99),
            meters=dataclasses.replace(s.meters),
            pool=self.pool.stats(),
        )

    def publish_metrics(self, registry=None) -> ServerStats:
        """Snapshot-set this server's stats into the metrics registry.

        Wired as the telemetry endpoint's ``on_scrape`` hook, so every
        ``/metrics`` scrape reads serving counters equal to
        :meth:`stats` field-for-field. Returns the published snapshot.
        """
        snap = self.stats()
        snap.to_metrics(registry)
        return snap

    def _health(self) -> dict:
        """The ``/healthz`` document: degraded on open breakers or a
        saturated queue, ok otherwise."""
        pool = self.pool.stats()
        saturated = self._pending >= self.max_queue
        status = (
            "degraded" if (pool.breakers_open or saturated) else "ok"
        )
        return {
            "status": status,
            "running": self._running,
            "queue_depth": self._pending,
            "max_queue": self.max_queue,
            "breakers_open": pool.breakers_open,
            "inflight_bytes": self._inflight_bytes,
        }

    def shutdown_telemetry(self) -> None:
        """Stop the scrape endpoint (if one was started)."""
        if self.telemetry is not None:
            self.telemetry.stop()
            self.telemetry = None

    # -- dispatcher ----------------------------------------------------------
    def _largest_bucket_key(self) -> tuple | None:
        best, best_len = None, 0
        for key, bucket in self._buckets.items():
            if len(bucket) > best_len:
                best, best_len = key, len(bucket)
        return best

    async def _dispatch_loop(self) -> None:
        while True:
            if self._pending == 0:
                if not self._running:
                    return
                self._wakeup.clear()
                # Re-check under the cleared flag: a submit between the
                # check above and clear() has already re-set the event.
                if self._pending == 0 and not self._running:
                    return
                await self._wakeup.wait()
                continue
            key = self._largest_bucket_key()
            bucket = self._buckets[key]
            if (
                self._running
                and len(bucket) < self.max_batch
                and self.max_wait_ms > 0
            ):
                # Batching window: let co-submitted compatible requests
                # land before cutting the batch. One bounded sleep — the
                # queue keeps filling while previous batches execute, so
                # saturated servers cut full batches without waiting.
                await asyncio.sleep(self.max_wait_ms / 1000.0)
                key = self._largest_bucket_key()
                bucket = self._buckets[key]
            batch = bucket[: self.max_batch]
            del bucket[: len(batch)]
            if not bucket:
                del self._buckets[key]
            self._pending -= len(batch)
            if _TRACER.enabled:
                _TRACER.instant(
                    "batch_cut",
                    cat="serving",
                    args={
                        "graph": key[0],
                        "size": len(batch),
                        "pending": self._pending,
                    },
                )
            async with self._space:
                self._space.notify_all()
            task = asyncio.create_task(self._run_one_batch(key[0], batch))
            self._tasks.add(task)
            task.add_done_callback(self._tasks.discard)

    # -- admission -----------------------------------------------------------
    async def _admit(self, graph_key: str, topo: float, attr: float) -> float:
        """Reserve in-flight bytes for one batch; returns the charged amount.

        The charge is graph-aware: the topology term is charged only by the
        first concurrently admitted batch on ``graph_key`` — later
        same-graph batches ride the existing reservation and charge only
        their attribute state. ``charge()`` is re-evaluated inside the wait
        predicate *and* at charge time under the same condition lock, so a
        batch that waited while the topology holder finished correctly
        re-charges topology itself (no double-charge, no free ride).
        """

        def charge() -> float:
            shared = self._graph_inflight.get(graph_key, 0) > 0
            return attr + (0.0 if shared else topo)

        async with self._admit_cv:
            if self.inflight_capacity is not None:
                await self._admit_cv.wait_for(
                    lambda: self._inflight_bytes == 0.0
                    or self._inflight_bytes + charge() <= self.inflight_capacity
                )
                if charge() > self.inflight_capacity:
                    self._stats.admission_overflows += 1
            estimate = charge()
            self._graph_inflight[graph_key] = (
                self._graph_inflight.get(graph_key, 0) + 1
            )
            self._inflight_bytes += estimate
            self._stats.peak_inflight_bytes = max(
                self._stats.peak_inflight_bytes, self._inflight_bytes
            )
            return estimate

    async def _release(self, graph_key: str, estimate: float) -> None:
        async with self._admit_cv:
            left = self._graph_inflight.get(graph_key, 0) - 1
            if left > 0:
                self._graph_inflight[graph_key] = left
            else:
                self._graph_inflight.pop(graph_key, None)
            self._inflight_bytes -= estimate
            self._admit_cv.notify_all()

    # -- execution -----------------------------------------------------------
    def _session_lock(self, graph_key: str) -> asyncio.Lock:
        lock = self._locks.get(graph_key)
        if lock is None:
            lock = self._locks[graph_key] = asyncio.Lock()
        return lock

    def _shed_expired(self, batch: list[_Pending]) -> list[_Pending]:
        """Resolve every past-deadline member with ``DeadlineExceeded``;
        return the still-live remainder."""
        now = time.perf_counter()
        alive = []
        for p in batch:
            if p.deadline_at is not None and now >= p.deadline_at:
                self._stats.timeouts += 1
                if not p.future.done():
                    p.future.set_exception(
                        DeadlineExceeded(
                            f"request on {p.graph_key!r} exceeded its "
                            f"{p.request.deadline_s}s deadline"
                        )
                    )
            else:
                alive.append(p)
        return alive

    @staticmethod
    def _deadline_cancel(batch: list[_Pending]):
        """A between-sweeps ``cancel`` callback for the batch's soonest
        deadline (None when no member carries one).

        ``session.run`` invokes it on every sweep boundary — vertex state
        is always a whole number of sweeps, so a cancelled batch leaves
        nothing torn and its surviving members re-run bit-identically.
        """
        deadlines = [p.deadline_at for p in batch if p.deadline_at is not None]
        if not deadlines:
            return None
        soonest = min(deadlines)

        def cancel(sweep: int) -> None:
            if time.perf_counter() >= soonest:
                raise DeadlineExceeded(
                    f"deadline reached at sweep boundary {sweep}"
                )

        return cancel

    async def _run_one_batch(self, graph_key: str, batch: list[_Pending]) -> None:
        loop = asyncio.get_running_loop()
        estimate = 0.0
        admitted = False
        locked = False
        lock = self._session_lock(graph_key)
        try:
            batch = self._shed_expired(batch)
            if not batch:
                return  # everything expired while queued — no work to run
            async with self._exec_sem:
                # Open (or page in) the session off-loop: staging a cold
                # graph is real work. Pin it against pool eviction.
                try:
                    session = await loop.run_in_executor(
                        self._executor, self.pool.acquire, graph_key
                    )
                except CircuitOpenError as exc:
                    self._stats.breaker_sheds += len(batch)
                    for p in batch:
                        if not p.future.done():
                            p.future.set_exception(exc)
                    return
                try:
                    plans = [p.request.plan for p in batch]
                    topo, attr = estimate_inflight_parts(
                        session, plans[0], len(plans)
                    )
                    estimate = await self._admit(graph_key, topo, attr)
                    admitted = True
                    await lock.acquire()
                    locked = True
                    attempt = 0
                    while True:
                        batch = self._shed_expired(batch)
                        if not batch:
                            return  # every member expired while retrying
                        plans = [p.request.plan for p in batch]
                        t_dispatch = time.perf_counter()
                        for p in batch:
                            if p.timing.dispatched == 0.0:
                                p.timing.dispatched = t_dispatch
                        try:
                            bres = await loop.run_in_executor(
                                self._executor,
                                functools.partial(
                                    session.run_batch,
                                    plans,
                                    cancel=self._deadline_cancel(batch),
                                ),
                            )
                            break
                        except DeadlineExceeded:
                            # The soonest-deadline member expired mid-run;
                            # the sweep-boundary cancel threw the whole
                            # batch away cleanly. Loop: shed it, re-run
                            # the survivors from scratch.
                            continue
                        except TransientFault:
                            self.pool.record_failure(graph_key)
                            budget = min(
                                p.request.max_retries for p in batch
                            )
                            if attempt >= budget:
                                raise
                            attempt += 1
                            self._stats.retries += 1
                            await asyncio.sleep(self.retry_backoff_s * attempt)
                finally:
                    self.pool.release(graph_key)
            t_done = time.perf_counter()
            if _TRACER.enabled:
                _TRACER.record(
                    "serve_batch",
                    t_dispatch,
                    t_done,
                    cat="serving",
                    args={
                        "graph": graph_key,
                        "size": len(batch),
                        "fused": bres.fused,
                    },
                )
            self.pool.record_success(graph_key)
            if self.watchdog.update(self._stats.batches, t_done - t_dispatch):
                self._stats.slow_batches += 1
            self._t_last = t_done
            if bres.fused:
                shares = split_meters(bres.meters, len(batch))
            else:
                # Sequential fallback: each member already owns its run's
                # meters (their merge is exactly the batch meters).
                shares = [r.meters for r in bres.results]
            self._stats.batches += 1
            self._stats.fused_batches += int(bres.fused)
            self._stats.batched_requests += len(batch)
            self._stats.max_occupancy = max(
                self._stats.max_occupancy, len(batch)
            )
            self._stats.meters.merge(bres.meters)
            for i, p in enumerate(batch):
                p.timing.completed = t_done
                self._stats.completed += 1
                self._lat_queue += p.timing.queue_s
                self._lat_run += p.timing.run_s
                self._lat_total += p.timing.total_s
                self._lat_max = max(self._lat_max, p.timing.total_s)
                self._lat_hist.observe(p.timing.total_s)
                _OBS_LATENCY.observe(p.timing.total_s)
                self._next_id += 1
                result = QueryResult(
                    request_id=self._next_id,
                    graph=graph_key,
                    result=bres.results[i],
                    meters=shares[i],
                    batch_size=len(batch),
                    fused=bres.fused,
                    timing=p.timing,
                )
                if not p.future.done():
                    p.future.set_result(result)
        except Exception as exc:  # propagate to every waiter, keep serving
            if not isinstance(exc, TransientFault):
                # Transient faults already fed the breaker per attempt.
                self.pool.record_failure(graph_key)
            self._stats.failed += len(batch)
            for p in batch:
                if not p.future.done():
                    p.future.set_exception(exc)
        finally:
            if locked:
                lock.release()
            if admitted:
                await self._release(graph_key, estimate)

    # -- driver integration ----------------------------------------------------
    def serve_plans(
        self, graph, plans: Sequence[ExecutionPlan], **session_kwargs
    ):
        """Serve K plans against one graph; returns a ``BatchResult``.

        The driver-facing entry (``multi_bfs(..., server=...)``): each plan
        becomes an individual :class:`QueryRequest`, flows through the
        queue/batcher/admission machinery, and the delivered results are
        re-assembled into the same :class:`~repro.core.session.BatchResult`
        shape ``session.run_batch`` returns — per-request meter shares
        merge back into the batch-level meters.
        """
        key = (
            self.pool.resolve(graph)
            if isinstance(graph, str)
            else self.pool.ensure(graph, **session_kwargs)
        )
        served = self.serve(
            [QueryRequest(graph=key, plan=plan) for plan in plans]
        )
        meters = Meters()
        for q in served:
            meters.merge(q.meters)
        return BatchResult(
            results=[q.result for q in served],
            meters=meters,
            iterations=max((q.result.iterations for q in served), default=0),
            converged=all(q.result.converged for q in served),
            fused=all(q.fused for q in served),
        )
