"""Serving: batched prefill/decode engine with sampling."""
from repro.serving.engine import Request, ServeEngine, sample_token

__all__ = ["Request", "ServeEngine", "sample_token"]
