"""repro.serving — async graph-query serving on top of GraphSession.

The online face of the engine: a bounded request queue, a dynamic
micro-batcher that fuses compatible point queries (BFS reachability,
personalized PageRank, SSSP distances) into single
:meth:`~repro.core.session.GraphSession.run_batch` passes, admission
control against the three-level memory budget, and a multi-graph
:class:`SessionPool` whose cold graphs page in from ``.dsss`` containers.

Quickstart::

    pool = SessionPool(capacity_bytes=1 << 30)
    pool.register("tw", "twitter.dsss", memory_budget=1 << 28)
    server = GraphServer(pool, max_batch=16, max_wait_ms=2.0)
    results = server.serve(
        [QueryRequest("tw", ExecutionPlan(BFS(), program_kwargs={"root": r}))
         for r in roots]
    )
    print(server.stats().qps, server.stats().mean_occupancy)

Every delivered result is bit-identical to a solo ``session.run(plan)``
and carries this request's exact share of the fused batch's meters.

Degradation knobs: ``QueryRequest(deadline_s=..., max_retries=...)``
sheds/cancels past-deadline requests at sweep boundaries
(:class:`~repro.reliability.faults.DeadlineExceeded` to the waiter,
``ServerStats.timeouts``) and re-runs transiently faulted batches with
backoff; ``SessionPool(breaker_threshold=...)`` sheds persistently
failing graphs via :class:`CircuitOpenError` until a cooldown expires.

The seed repo's LLM token-generation demo lives in
:mod:`repro.serving.llm_demo` (import it explicitly); this package's
public API is graph serving only.
"""
from repro.reliability.faults import DeadlineExceeded, TransientFault
from repro.serving.api import (
    AdmissionError,
    QueryRequest,
    QueryResult,
    RequestTiming,
    ServerStats,
    split_meters,
)
from repro.serving.pool import CircuitOpenError, PoolStats, SessionPool
from repro.serving.server import GraphServer, estimate_inflight_bytes

__all__ = [
    "AdmissionError",
    "CircuitOpenError",
    "DeadlineExceeded",
    "GraphServer",
    "PoolStats",
    "QueryRequest",
    "QueryResult",
    "RequestTiming",
    "ServerStats",
    "SessionPool",
    "TransientFault",
    "estimate_inflight_bytes",
    "split_meters",
]
