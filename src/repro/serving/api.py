"""Request/result schema for graph-query serving.

A :class:`QueryRequest` names a graph (a :class:`~repro.serving.pool.
SessionPool` key, or a :class:`~repro.core.dsss.DSSSGraph` object that the
pool auto-registers) and carries one frozen
:class:`~repro.core.plan.ExecutionPlan` — the same hashable job
description ``session.run`` takes, so anything runnable solo is servable.
The server answers with a :class:`QueryResult`: the per-query
:class:`~repro.core.session.Result` (bit-identical to a solo
``session.run(plan)``), this request's *share* of the fused batch's
:class:`~repro.core.session.Meters`, the occupancy of the batch it rode,
and its enqueue→dispatch→complete timing.

Meter shares (:func:`split_meters`): ``run_batch`` charges edge bytes once
for the shared streamed pass and interval/hub bytes K× (each query owns
its attribute state), all into one batch-level ``Meters``. A share divides
every additive field by K such that the K shares recombine *exactly* —
integer fields by ``divmod`` (the first ``remainder`` shares carry one
extra), byte fields (integral floats) the same way, and residual float
fields (``wall_seconds``) by assigning the last share the exact remainder
of the running sum. ``peak_device_graph_bytes`` is a high-water mark, not
a flow: every share reports the batch peak, and ``Meters.merge`` (which
maxes that field) reconstructs the batch meters field-for-field.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.plan import ExecutionPlan
from repro.core.session import Meters, Result

__all__ = [
    "AdmissionError",
    "QueryRequest",
    "QueryResult",
    "RequestTiming",
    "ServerStats",
    "split_meters",
]


class AdmissionError(RuntimeError):
    """The server refused a request (queue full under the reject policy)."""


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    """One point query: which graph, and what job to run on it.

    ``graph`` is a pool key (``str``) or a ``DSSSGraph`` object —
    object-valued graphs are auto-registered in the server's pool by
    identity. ``plan`` is the frozen job description; requests whose
    ``(graph, plan.batch_key())`` agree are candidates for fusion into one
    ``run_batch`` pass (they may differ only in Initialize kwargs, e.g.
    BFS roots).

    ``deadline_s`` is a soft per-request budget measured from enqueue:
    once exceeded the server sheds the request from the queue, or — if it
    is already riding a batch — cancels the batch cooperatively at the
    next sweep boundary and re-runs the surviving members. The waiter
    receives :class:`~repro.reliability.faults.DeadlineExceeded`;
    ``ServerStats.timeouts`` counts it. ``max_retries`` bounds how many
    times the server re-runs this request's batch after a
    :class:`~repro.reliability.faults.TransientFault` (a fused batch
    retries under the *smallest* member budget).
    """

    graph: Any
    plan: ExecutionPlan
    deadline_s: float | None = None
    max_retries: int = 0

    def __post_init__(self):
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be positive (or None)")
        if self.max_retries < 0:
            raise ValueError("max_retries must be ≥ 0")


@dataclasses.dataclass
class RequestTiming:
    """Monotonic timestamps of one request's life cycle (seconds)."""

    enqueued: float = 0.0
    dispatched: float = 0.0
    completed: float = 0.0

    @property
    def queue_s(self) -> float:
        """Time spent waiting in the batcher's queue."""
        return self.dispatched - self.enqueued

    @property
    def run_s(self) -> float:
        """Dispatch→complete time of the batch this request rode."""
        return self.completed - self.dispatched

    @property
    def total_s(self) -> float:
        return self.completed - self.enqueued


@dataclasses.dataclass
class QueryResult:
    """A served query: the solo-identical result plus serving metadata."""

    request_id: int
    graph: str  # resolved pool key
    result: Result  # bit-identical to session.run(plan)
    meters: Meters  # this request's share of the batch meters
    batch_size: int  # occupancy of the dispatched batch
    fused: bool  # False if the batch fell back to sequential runs
    timing: RequestTiming

    @property
    def output(self):
        return self.result.output

    @property
    def attrs(self):
        return self.result.attrs


@dataclasses.dataclass
class ServerStats:
    """A point-in-time snapshot of the server's counters.

    ``qps`` is completed requests over the first-enqueue→last-completion
    window; ``mean_occupancy`` is requests-per-dispatched-batch (the
    micro-batching win: occupancy K means edge bytes were paid once for K
    queries). ``meters`` accumulates every batch's meters via
    ``Meters.merge`` — its edge bytes divided by ``completed`` is the
    served cost per query. ``peak_inflight_bytes`` is the admission
    controller's high-water mark of concurrently admitted in-flight
    bytes (device topology + attribute state, model units) and stays
    ≤ ``inflight_capacity`` whenever every batch fits capacity alone
    (``admission_overflows`` counts the documented solo-run exceptions).
    """

    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    failed: int = 0
    timeouts: int = 0  # requests shed/cancelled past their deadline_s
    retries: int = 0  # batch re-runs after a TransientFault
    breaker_sheds: int = 0  # requests shed by an open circuit breaker
    slow_batches: int = 0  # batches the straggler watchdog flagged
    batches: int = 0
    fused_batches: int = 0
    batched_requests: int = 0
    max_occupancy: int = 0
    queue_depth: int = 0
    inflight_bytes: float = 0.0
    peak_inflight_bytes: float = 0.0
    admission_overflows: int = 0
    qps: float = 0.0
    mean_queue_s: float = 0.0
    mean_run_s: float = 0.0
    mean_total_s: float = 0.0
    max_total_s: float = 0.0
    # Estimated from the server's fixed-bucket latency histogram
    # (repro.obs.DEFAULT_LATENCY_BUCKETS) — exact at bucket edges.
    p50_total_s: float = 0.0
    p95_total_s: float = 0.0
    p99_total_s: float = 0.0
    meters: Meters = dataclasses.field(default_factory=Meters)
    pool: Any = None  # PoolStats of the backing SessionPool

    #: Monotone request/batch tallies — published as ``repro_serving_
    #: <field>_total`` counters.
    COUNTER_FIELDS = (
        "submitted", "completed", "rejected", "failed", "timeouts",
        "retries", "breaker_sheds", "slow_batches", "batches",
        "fused_batches", "batched_requests", "admission_overflows",
    )
    #: Point-in-time levels/derived rates — published as ``repro_serving_
    #: <field>`` gauges.
    GAUGE_FIELDS = (
        "max_occupancy", "queue_depth", "inflight_bytes",
        "peak_inflight_bytes", "qps", "mean_queue_s", "mean_run_s",
        "mean_total_s", "max_total_s", "p50_total_s", "p95_total_s",
        "p99_total_s",
    )

    @property
    def mean_occupancy(self) -> float:
        return self.batched_requests / self.batches if self.batches else 0.0

    def to_metrics(self, registry=None) -> None:
        """Publish this snapshot into ``registry`` (default: process-wide).

        Snapshot-set semantics: every serving series is *set* to the
        snapshot's value rather than incremented, so a ``/metrics``
        scrape taken right after ``to_metrics`` reads numbers equal to
        this object field-for-field (``GraphServer`` wires this as the
        telemetry endpoint's ``on_scrape`` hook — the CI consistency
        gate relies on the equality). The accumulated serving ``meters``
        go out as ``repro_serving_meters_total{field=...}``, one series
        per :class:`~repro.core.session.Meters` field, so per-request
        ``split_meters`` shares provably re-sum to the scraped totals.
        """
        from repro.obs.registry import REGISTRY

        reg = registry if registry is not None else REGISTRY
        for f in self.COUNTER_FIELDS:
            reg.counter(
                f"repro_serving_{f}_total", f"ServerStats.{f} snapshot"
            ).set(getattr(self, f))
        for f in self.GAUGE_FIELDS:
            reg.gauge(
                f"repro_serving_{f}", f"ServerStats.{f} snapshot"
            ).set(getattr(self, f))
        reg.gauge(
            "repro_serving_mean_occupancy", "Requests per dispatched batch"
        ).set(self.mean_occupancy)
        meters_fam = reg.counter(
            "repro_serving_meters_total",
            "Accumulated serving Meters, by field",
            ("field",),
        )
        for f in dataclasses.fields(Meters):
            meters_fam.labels(field=f.name).set(
                float(getattr(self.meters, f.name))
            )
        if self.pool is not None and hasattr(self.pool, "to_metrics"):
            self.pool.to_metrics(reg)


def _split_integral(total: int, k: int) -> list[int]:
    q, r = divmod(int(total), k)
    return [q + 1 if i < r else q for i in range(k)]


def split_meters(total: Meters, k: int) -> list[Meters]:
    """Split one batch-level ``Meters`` into K per-request shares.

    Recombining the shares with ``Meters.merge`` reproduces ``total``
    exactly for every integer field and every byte field (bytes are
    integral floats — ``e·Be`` / ``interval_size·Ba`` charges — and split
    by ``divmod``, whose parts sum exactly); the
    ``peak_device_graph_bytes`` high-water mark is replicated (``merge``
    maxes it). The only non-integral field, ``wall_seconds``, gives the
    last share the remainder of the running sum — exact up to one final
    rounding.
    """
    if k <= 0:
        raise ValueError("k must be positive")
    shares = [Meters() for _ in range(k)]
    for f in dataclasses.fields(Meters):
        v = getattr(total, f.name)
        if f.name == "peak_device_graph_bytes":
            for s in shares:
                setattr(s, f.name, v)
        elif isinstance(v, int):
            for s, part in zip(shares, _split_integral(v, k)):
                setattr(s, f.name, part)
        elif float(v).is_integer() and abs(v) < 2**53:
            for s, part in zip(shares, _split_integral(int(v), k)):
                setattr(s, f.name, float(part))
        else:
            per = v / k
            acc = 0.0
            for s in shares[:-1]:
                setattr(s, f.name, per)
                acc += per
            setattr(shares[-1], f.name, v - acc)
    return shares
