"""Multi-graph session pool with explicit staged-bytes capacity.

Evolves the module-level ``get_session`` LRU into a first-class object the
server can do admission control against: each registered graph (an
in-memory :class:`~repro.core.dsss.DSSSGraph` or a ``.dsss`` path) opens
lazily into a :class:`~repro.core.session.GraphSession`, the pool accounts
the host RAM each open session's staged buffers occupy
(:meth:`GraphSession.staged_host_bytes`), and least-recently-used idle
sessions are evicted when ``capacity_bytes`` / ``max_open`` would be
exceeded. Evicting a path-registered graph is cheap to undo — the next
query pages it back in from the ``.dsss`` container via
:meth:`GraphSession.open` (mmap views, nothing edge-scale in RAM);
object-registered graphs restage from the in-memory arrays.

Sessions with in-flight work are pinned (``acquire``/``release`` refcount)
and never evicted mid-run. All entry state is guarded by one reentrant
lock, so pin/evict/open races from the server's executor threads can't
interleave: ``acquire`` opens-and-pins atomically (no window where a
fresh session is evictable before its pin lands), and a concurrent
double-open of a cold entry can't strand a second staged copy's bytes.

Per-graph **circuit breaker**: when ``breaker_threshold`` consecutive
failures are recorded against a graph (:meth:`record_failure`), its
breaker opens and ``acquire`` sheds with :class:`CircuitOpenError` for
``breaker_cooldown_s`` — a persistently failing graph stops burning
executor slots and retry budgets. After the cooldown one trial request is
let through (half-open); :meth:`record_success` closes the breaker and
clears the failure count, while a trial failure re-trips it immediately
(the count is retained across the half-open transition).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Any

from repro.core.dsss import DSSSGraph
from repro.core.session import GraphSession
from repro.obs.registry import REGISTRY as _REGISTRY

_OBS_BREAKER_TRIPS = _REGISTRY.counter(
    "repro_pool_breaker_trips_total",
    "Circuit breakers (re-)tripped by consecutive failures",
)

__all__ = ["CircuitOpenError", "PoolStats", "SessionPool"]


class CircuitOpenError(RuntimeError):
    """The graph's circuit breaker is open — request shed, not run."""


@dataclasses.dataclass
class PoolStats:
    """Snapshot of the pool's staging ledger."""

    registered: int = 0
    open_sessions: int = 0
    staged_bytes: int = 0  # host RAM of all open sessions' staged buffers
    capacity_bytes: int | None = None
    opens: int = 0  # sessions staged (first open or re-open after evict)
    evictions: int = 0
    hits: int = 0  # session() calls served by an already-open session
    breakers_open: int = 0  # graphs currently shedding via CircuitOpenError

    def to_metrics(self, registry=None) -> None:
        """Publish this snapshot (snapshot-set, like ``ServerStats``)."""
        from repro.obs.registry import REGISTRY

        reg = registry if registry is not None else REGISTRY
        for f in ("registered", "open_sessions", "staged_bytes",
                  "breakers_open"):
            reg.gauge(f"repro_pool_{f}", f"PoolStats.{f} snapshot").set(
                getattr(self, f)
            )
        for f in ("opens", "evictions", "hits"):
            reg.counter(
                f"repro_pool_{f}_total", f"PoolStats.{f} snapshot"
            ).set(getattr(self, f))


@dataclasses.dataclass
class _Entry:
    name: str
    source: Any  # DSSSGraph | str (.dsss path)
    kwargs: dict
    session: GraphSession | None = None
    in_use: int = 0
    failures: int = 0  # consecutive failures since the last success
    open_until: float = 0.0  # monotonic deadline while the breaker is open


class SessionPool:
    """Named graphs → lazily opened, capacity-bounded ``GraphSession``\\ s.

    Args:
      capacity_bytes: bound on the summed
        :meth:`~repro.core.session.GraphSession.staged_host_bytes` of open
        sessions. ``None`` = unbounded. The bound is enforced by evicting
        idle LRU sessions *before* each open; a single graph larger than
        the capacity still opens (it alone defines the working set) —
        mirroring ``memory_budget`` semantics, where the budget shapes
        residency rather than refusing the graph.
      max_open: bound on simultaneously open sessions (the old
        ``get_session`` LRU's size-8 analogue).
      breaker_threshold: consecutive :meth:`record_failure` calls on one
        graph before its breaker opens (``None`` disables the breaker).
      breaker_cooldown_s: how long an open breaker sheds before letting a
        half-open trial through.
    """

    def __init__(
        self,
        *,
        capacity_bytes: int | None = None,
        max_open: int = 8,
        breaker_threshold: int | None = None,
        breaker_cooldown_s: float = 30.0,
    ):
        if breaker_threshold is not None and breaker_threshold < 1:
            raise ValueError("breaker_threshold must be ≥ 1 (or None)")
        if breaker_cooldown_s < 0:
            raise ValueError("breaker_cooldown_s must be ≥ 0")
        self.capacity_bytes = capacity_bytes
        self.max_open = max_open
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self._lock = threading.RLock()
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self._opens = 0
        self._evictions = 0
        self._hits = 0

    # -- registration --------------------------------------------------------
    def register(self, name: str, source, **session_kwargs) -> str:
        """Register a graph under ``name``.

        ``source`` is a ``DSSSGraph`` (staged in-memory on open) or a
        ``str`` path to a ``.dsss`` container (opened disk-backed via
        :meth:`GraphSession.open`; cold graphs page in from the file).
        ``session_kwargs`` (memory_budget, host_memory_budget, residency,
        execution, packing, Be, Bv) are applied at every (re-)open.
        """
        if not isinstance(source, (DSSSGraph, str)):
            raise TypeError(
                "source must be a DSSSGraph or a .dsss path, "
                f"got {type(source).__name__}"
            )
        with self._lock:
            if name in self._entries:
                raise ValueError(f"graph {name!r} already registered")
            self._entries[name] = _Entry(
                name=name, source=source, kwargs=session_kwargs
            )
        return name

    def ensure(self, graph: DSSSGraph, **session_kwargs) -> str:
        """Auto-register an anonymous graph object by identity (idempotent).

        The pool holds a strong reference to the graph for the entry's
        lifetime — use :meth:`register` with an explicit name (or a
        ``.dsss`` path) for long-lived servers.
        """
        kw_tag = hash(tuple(sorted(session_kwargs.items()))) & 0xFFFF
        # id() is unique among live objects and the entry holds a strong
        # reference, so an existing entry under this name is this graph.
        name = f"graph@{id(graph):x}/{kw_tag:04x}"
        with self._lock:
            if name not in self._entries:
                self.register(name, graph, **session_kwargs)
        return name

    def resolve(self, graph) -> str:
        """Normalize a request's ``graph`` field to a pool key."""
        if isinstance(graph, str):
            with self._lock:
                if graph not in self._entries:
                    raise KeyError(f"graph {graph!r} is not registered")
            return graph
        if isinstance(graph, DSSSGraph):
            return self.ensure(graph)
        raise TypeError(
            "QueryRequest.graph must be a registered name or a DSSSGraph, "
            f"got {type(graph).__name__}"
        )

    def names(self) -> list[str]:
        with self._lock:
            return list(self._entries)

    # -- access --------------------------------------------------------------
    def session(self, name: str) -> GraphSession:
        """The (opened) session for ``name``; LRU-bumps the entry."""
        with self._lock:
            entry = self._entries[name]
            if entry.session is None:
                self._open(entry)
            else:
                self._hits += 1
            self._entries.move_to_end(name)
            return entry.session

    def acquire(self, name: str) -> GraphSession:
        """Like :meth:`session`, and pins the entry against eviction.

        Open-and-pin is atomic under the pool lock — a concurrent
        ``_evict_to_fit`` can never observe the freshly opened session
        with a zero refcount and evict it out from under the caller.
        Sheds with :class:`CircuitOpenError` while the graph's breaker is
        open; after ``breaker_cooldown_s`` one trial acquire is let
        through (half-open — the failure count is retained so a failed
        trial re-trips instantly).
        """
        with self._lock:
            entry = self._entries[name]
            if entry.open_until:
                if time.monotonic() < entry.open_until:
                    raise CircuitOpenError(
                        f"graph {name!r}: circuit open after "
                        f"{entry.failures} consecutive failures "
                        f"(cooldown {self.breaker_cooldown_s}s)"
                    )
                entry.open_until = 0.0  # half-open: let one trial through
            session = self.session(name)
            entry.in_use += 1
            return session

    def release(self, name: str) -> None:
        with self._lock:
            entry = self._entries[name]
            if entry.in_use <= 0:
                raise RuntimeError(f"release() without acquire() for {name!r}")
            entry.in_use -= 1
            # The unpin may make this entry the eviction candidate the
            # pool has been waiting for; re-enforce the bounds now rather
            # than leaving stale staged bytes resident until the next
            # open. (Never evicts still-pinned or just-released-but-
            # re-acquired entries — the refcount is authoritative.)
            self._evict_to_fit(keep="")

    def evict(self, name: str) -> bool:
        """Drop ``name``'s open session (no-op if cold or in use)."""
        with self._lock:
            entry = self._entries[name]
            if entry.session is None or entry.in_use > 0:
                return False
            entry.session = None
            self._evictions += 1
            return True

    # -- circuit breaker -----------------------------------------------------
    def record_failure(self, name: str) -> bool:
        """Count one failed run against ``name``; returns True if the
        breaker (re-)tripped."""
        with self._lock:
            entry = self._entries[name]
            entry.failures += 1
            if (
                self.breaker_threshold is not None
                and entry.failures >= self.breaker_threshold
            ):
                entry.open_until = time.monotonic() + self.breaker_cooldown_s
                _OBS_BREAKER_TRIPS.inc()
                return True
            return False

    def record_success(self, name: str) -> None:
        """A run on ``name`` succeeded — close its breaker, reset the count."""
        with self._lock:
            entry = self._entries[name]
            entry.failures = 0
            entry.open_until = 0.0

    def breaker_open(self, name: str) -> bool:
        with self._lock:
            return time.monotonic() < self._entries[name].open_until

    # -- accounting ----------------------------------------------------------
    def staged_bytes(self) -> int:
        """Summed host RAM of every open session's staged buffers (live —
        disk-backed sessions grow as their RAM caches materialize)."""
        with self._lock:
            return sum(
                int(e.session.staged_host_bytes())
                for e in self._entries.values()
                if e.session is not None
            )

    def stats(self) -> PoolStats:
        with self._lock:
            now = time.monotonic()
            return PoolStats(
                registered=len(self._entries),
                open_sessions=sum(
                    1 for e in self._entries.values() if e.session is not None
                ),
                staged_bytes=self.staged_bytes(),
                capacity_bytes=self.capacity_bytes,
                opens=self._opens,
                evictions=self._evictions,
                hits=self._hits,
                breakers_open=sum(
                    1 for e in self._entries.values() if now < e.open_until
                ),
            )

    # -- internals (callers hold self._lock) ---------------------------------
    def _open(self, entry: _Entry) -> None:
        if isinstance(entry.source, str):
            entry.session = GraphSession.open(entry.source, **entry.kwargs)
        else:
            entry.session = GraphSession(entry.source, **entry.kwargs)
        self._opens += 1
        self._evict_to_fit(keep=entry.name)

    def _evict_to_fit(self, keep: str) -> None:
        """Evict idle LRU sessions until capacity/max_open hold.

        The just-opened ``keep`` entry is never evicted: one graph larger
        than the capacity runs alone rather than thrashing. Pinned entries
        (``in_use > 0``) are likewise never victims — when everything
        evictable is pinned the bounds are temporarily exceeded and
        :meth:`release` re-enforces them as pins drop.
        """

        def over() -> bool:
            n_open = sum(
                1 for e in self._entries.values() if e.session is not None
            )
            if n_open > self.max_open:
                return True
            return (
                self.capacity_bytes is not None
                and self.staged_bytes() > self.capacity_bytes
            )

        while over():
            victim = next(
                (
                    e
                    for e in self._entries.values()  # LRU order
                    if e.session is not None and e.in_use == 0 and e.name != keep
                ),
                None,
            )
            if victim is None:
                break  # everything else is in use — nothing evictable
            self.evict(victim.name)
