"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax init,
and tests/benches must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips of v5e) or 2×16×16 multi-pod (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small shapes on forced host devices)."""
    return jax.make_mesh(shape, axes)
