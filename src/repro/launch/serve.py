"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``."""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import Model
    from repro.serving.llm_demo import Request, ServeEngine

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=8)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(
            Request(
                request_id=i,
                prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).tolist(),
                max_new_tokens=args.max_new,
                temperature=args.temperature,
            )
        )
    results = eng.run()
    for rid in sorted(results):
        print(f"request {rid}: {results[rid]}")


if __name__ == "__main__":
    main()
