"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On a real multi-host pod this process would call
``jax.distributed.initialize()`` first (host topology from the scheduler),
build the production mesh, and shard the data loader by host id. On this
container it drives the same fault-tolerant loop on one device.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-sync", default="none", choices=["none", "compressed_bf16"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.train.loop import TrainLoopConfig, train

    cfg = get_config(args.arch, smoke=args.smoke)
    loop = TrainLoopConfig(
        total_steps=args.steps,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir,
        seq_len=args.seq_len,
        global_batch=args.batch,
        learning_rate=args.lr,
        accum_steps=args.accum,
        grad_sync=args.grad_sync,
    )
    stats = train(cfg, loop)
    print(
        f"done: steps={stats['final_step']} loss {stats['first_loss']:.3f} "
        f"-> {stats['last_loss']:.3f} recoveries={stats['recoveries']}"
    )


if __name__ == "__main__":
    main()
