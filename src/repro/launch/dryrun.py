import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
).strip()
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture × applicable shape × mesh) cell:
  1. build ShapeDtypeStruct inputs with NamedShardings attached,
  2. ``jax.jit(step).lower(...)`` then ``.compile()`` on the production mesh
     (16×16 single-pod / 2×16×16 multi-pod of host placeholder devices),
  3. print ``memory_analysis()`` (fits-HBM proof) and ``cost_analysis()``,
  4. parse the optimized HLO for collective bytes,
  5. emit the three roofline terms to a JSON cache for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shape_is_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import decode_step, input_specs, prefill
from repro.optim import AdamW
from repro.runtime.analytic_cost import analytic_cost
from repro.runtime.hlo_analysis import HW, RooflineReport
from repro.runtime.hlo_loops import collective_bytes_weighted
from repro.sharding.rules import (
    SERVING_RULES,
    TRAIN_FSDP_RULES,
    activate_mesh,
    batch_spec,
    cache_specs,
    named_sharding,
    tree_shardings,
)
from repro.train.state import abstract_train_state
from repro.train.step import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def _attach(specs_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs_tree,
        shardings_tree,
    )


def _batch_shardings(specs: dict, mesh) -> dict:
    out = {}
    for k, v in specs.items():
        bs = batch_spec(mesh, v.shape[0])
        spec = P(*(list(bs) + [None] * (len(v.shape) - len(bs))))
        out[k] = NamedSharding(mesh, spec)
    return out


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = mesh.devices.size

    if shape.kind == "train":
        import dataclasses as _dc

        # bf16 stored params + fp32 Adam moments: weight all-gathers and
        # gradient reduce-scatters move half the bytes (§Perf iteration).
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
        opt = AdamW(learning_rate=1e-4, weight_decay=0.1)
        # Train profile v2 (pure FSDP / ZeRO-3, see sharding/rules.py):
        # batch DP over every axis, params 2-D sharded + gathered per layer,
        # no TP activation all-reduces. accum=1: activations are fully
        # batch-sharded so the saved-residual stack is small.
        rules = TRAIN_FSDP_RULES if os.environ.get("REPRO_TRAIN_RULES", "fsdp") == "fsdp" else None
        # accum=1 when the global batch fills the mesh (activations fully
        # sharded); otherwise microbatch to bound the saved-residual stack
        # (multi-pod: 256-seq batch on 512 chips shards only 32-way).
        accum = 1 if (rules is not None and shape.global_batch % chips == 0) else 4
        step_fn = make_train_step(cfg, opt, accum_steps=accum)
        state = abstract_train_state(cfg, opt)
        state_sh = tree_shardings(state, mesh, rules=rules)
        state_in = _attach(state, state_sh)
        specs = input_specs(cfg, shape)
        batch_in = _attach(specs, _batch_shardings(specs, mesh))
        with mesh, activate_mesh(mesh, rules):
            jitted = jax.jit(
                step_fn,
                donate_argnums=(0,),
                out_shardings=(
                    jax.tree.map(lambda s: s, state_sh),
                    None,
                ),
            )
            lowered = jitted.lower(state_in, batch_in)
    elif shape.kind == "prefill":
        opt = AdamW()
        state = abstract_train_state(cfg, opt)
        params = state["params"]
        params_sh = tree_shardings(params, mesh, rules=SERVING_RULES)
        params_in = _attach(params, params_sh)
        specs = input_specs(cfg, shape)
        batch_in = _attach(specs, _batch_shardings(specs, mesh))

        def prefill_fn(params, inputs):
            extra = {k: v for k, v in inputs.items() if k != "tokens"}
            return prefill(
                cfg, params, inputs["tokens"], max_len=shape.seq_len, **extra
            )

        with mesh, activate_mesh(mesh):
            lowered = jax.jit(prefill_fn).lower(params_in, batch_in)
    else:  # decode
        opt = AdamW()
        state = abstract_train_state(cfg, opt)
        params = state["params"]
        params_sh = tree_shardings(params, mesh, rules=SERVING_RULES)
        params_in = _attach(params, params_sh)
        specs = input_specs(cfg, shape)
        cache_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            cache_specs(specs["cache"], mesh),
            is_leaf=lambda x: isinstance(x, P),
        )
        cache_in = _attach(specs["cache"], cache_sh)
        tok_in = jax.ShapeDtypeStruct(
            specs["token"].shape,
            specs["token"].dtype,
            sharding=NamedSharding(mesh, batch_spec(mesh, shape.global_batch)),
        )
        pos_in = jax.ShapeDtypeStruct((), jnp.int32)

        def decode_fn(params, cache, token, pos):
            return decode_step(cfg, params, cache, token, pos)

        with mesh, activate_mesh(mesh):
            lowered = jax.jit(decode_fn, donate_argnums=(1,)).lower(
                params_in, cache_in, tok_in, pos_in
            )
    return cfg, lowered, chips


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str | None):
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    cfg, lowered, chips = lower_cell(arch, shape_name, mesh, mesh_name)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    shape = SHAPES[shape_name]

    def _mem_field(name):
        try:
            return float(getattr(mem, name))
        except Exception:
            return float("nan")

    bytes_per_chip = sum(
        v
        for v in (
            _mem_field("argument_size_in_bytes"),
            _mem_field("output_size_in_bytes"),
            _mem_field("temp_size_in_bytes"),
        )
        if v == v
    )
    # donated args alias outputs; peak live is ~ max(arg, out) + temp.
    args_b = _mem_field("argument_size_in_bytes")
    out_b = _mem_field("output_size_in_bytes")
    temp_b = _mem_field("temp_size_in_bytes")
    peak = max(args_b, out_b) + (temp_b if temp_b == temp_b else 0.0)

    # Roofline terms. FLOPs/HBM come from the analytic model (XLA's
    # cost_analysis counts while-loop bodies once — wrong for scanned
    # stacks; see runtime/analytic_cost.py); collectives come from the
    # trip-count-weighted HLO parse; cost_analysis stays as a diagnostic.
    hw = HW()
    ana = analytic_cost(cfg, shape)
    n_active = cfg.active_params()
    coll = collective_bytes_weighted(hlo)
    coll_total = float(sum(coll.values()))
    rep = RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=ana.flops_global / chips,
        hlo_bytes=ana.hbm_bytes_global / chips,
        coll_bytes_per_chip=coll_total,
        coll_breakdown=coll,
        model_flops=ana.model_flops,
        bytes_per_chip_peak=peak,
    )
    rep.compute_s = rep.hlo_flops / hw.peak_flops
    rep.memory_s = rep.hlo_bytes / hw.hbm_bw
    rep.collective_s = coll_total / hw.ici_bw
    result = rep.to_dict()
    result.update(
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=dict(
            argument_size_in_bytes=args_b,
            output_size_in_bytes=out_b,
            temp_size_in_bytes=temp_b,
            peak_estimate=peak,
        ),
        analytic=dict(
            flops_global=ana.flops_global,
            hbm_bytes_global=ana.hbm_bytes_global,
            notes=ana.notes,
        ),
        cost_analysis_diag={
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float))
            and k in ("flops", "bytes accessed", "transcendentals")
        },
        params=cfg.num_params(),
        active_params=n_active,
    )
    print(f"== {arch} × {shape_name} × {mesh_name} ({chips} chips) ==")
    print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s")
    print(f"  memory_analysis: args={args_b/1e9:.2f}GB out={out_b/1e9:.2f}GB "
          f"temp={temp_b/1e9:.2f}GB peak≈{peak/1e9:.2f}GB/chip "
          f"(HBM {HW().hbm_bytes/1e9:.0f}GB: {'FITS' if peak < HW().hbm_bytes else 'OVER'})")
    print(f"  cost_analysis: flops={rep.hlo_flops:.3e} bytes={rep.hlo_bytes:.3e}")
    print(f"  collectives/chip: {rep.coll_bytes_per_chip:.3e} B {rep.coll_breakdown}")
    print(f"  roofline: compute={rep.compute_s*1e3:.2f}ms memory={rep.memory_s*1e3:.2f}ms "
          f"collective={rep.collective_s*1e3:.2f}ms dominant={rep.dominant} "
          f"useful_flops_ratio={rep.useful_flops_ratio:.3f} "
          f"roofline_fraction={rep.roofline_fraction:.3f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=2, default=str)
        print(f"  -> {fn}")
    return result


def run_graph_cell(name: str, mesh_name: str, out_dir: str | None):
    """Dry-run one paper-scale graph on the production mesh: lower+compile
    the shard_map PageRank step (core/distributed.py) from SDS inputs."""
    from repro.core.distributed import (
        GRAPH_SCALES,
        graph_input_specs,
        make_pagerank_step,
    )

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    src_axes = ("pod", "data") if mesh_name == "multi" else ("data",)
    specs = graph_input_specs(name, mesh, src_axes=src_axes)
    step, _ = make_pagerank_step(
        mesh, specs["n"], specs["n_pad"], src_axes=src_axes
    )
    lowered = step.lower(
        specs["x"], specs["dang"], specs["src_l"], specs["dst_l"], specs["w"]
    )
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    chips = mesh.devices.size
    n, m = GRAPH_SCALES[name]
    coll = collective_bytes_weighted(hlo)
    coll_total = float(sum(coll.values()))
    hw = HW()
    # analytic: per edge one mul+add (gather+weight) + one add (segment).
    flops = 3.0 * m
    # HBM: edges (src,dst,w = 12 B) + x gather + hub write/read + y.
    hbm = 12.0 * m + 4.0 * m + 3 * 4.0 * n
    rep = RooflineReport(
        arch=f"graph:{name}",
        shape="pagerank_iter",
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops / chips,
        hlo_bytes=hbm / chips,
        coll_bytes_per_chip=coll_total,
        coll_breakdown=coll,
        model_flops=2.0 * m,
        bytes_per_chip_peak=float(getattr(mem, "temp_size_in_bytes", 0.0))
        + float(getattr(mem, "argument_size_in_bytes", 0.0)),
    )
    rep.compute_s = rep.hlo_flops / hw.peak_flops
    rep.memory_s = rep.hlo_bytes / hw.hbm_bw
    rep.collective_s = coll_total / hw.ici_bw
    result = rep.to_dict()
    result.update(lower_s=round(t_lower, 1), compile_s=round(t_compile, 1))
    print(f"== graph:{name} × pagerank × {mesh_name} ({chips} chips) ==")
    print(
        f"  args={float(getattr(mem,'argument_size_in_bytes',0))/1e9:.2f}GB "
        f"temp={float(getattr(mem,'temp_size_in_bytes',0))/1e9:.2f}GB "
        f"compile {t_compile:.1f}s"
    )
    print(
        f"  roofline: compute={rep.compute_s*1e3:.2f}ms memory={rep.memory_s*1e3:.2f}ms "
        f"collective={rep.collective_s*1e3:.2f}ms dominant={rep.dominant}"
    )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"graph-{name}__pagerank__{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=2, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--graphs", action="store_true", help="graph-engine cells")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.graphs:
        from repro.core.distributed import GRAPH_SCALES

        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = []
        for name in GRAPH_SCALES:
            for mesh_name in meshes:
                try:
                    run_graph_cell(name, mesh_name, args.out)
                except Exception as e:
                    failures.append((name, mesh_name, repr(e)))
                    traceback.print_exc()
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("\nAll graph dry-run cells passed.")
        return

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            if not shape_is_applicable(arch, shape):
                print(f"-- skip {arch} × {shape} (inapplicable; see DESIGN.md)")
                continue
            for mesh_name in meshes:
                fn = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}.json"
                )
                if args.skip_existing and os.path.exists(fn):
                    print(f"-- cached {fn}")
                    continue
                try:
                    run_cell(arch, shape, mesh_name, args.out)
                except Exception as e:
                    failures.append((arch, shape, mesh_name, repr(e)))
                    print(f"!! FAIL {arch} × {shape} × {mesh_name}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
