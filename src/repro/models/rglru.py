"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block: x → [branch a: linear → causal conv(4) → RG-LRU] ⊙ [branch b:
linear → GeLU] → out-proj. The RG-LRU diagonal recurrence

    r_t = σ(Wa x_t + ba)                 (recurrence gate)
    i_t = σ(Wx x_t + bx)                 (input gate)
    a_t = exp(c·softplus(Λ)·(−r_t))      (per-channel decay, c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1−a_t²) ⊙ (i_t ⊙ x_t)

runs as a parallel associative scan over the sequence (train/prefill) or a
single fused update (decode, O(1) state) — this is why recurrentgemma-9b
is long_500k-applicable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.ssm import _causal_conv
from repro.sharding.rules import maybe_constrain

__all__ = ["rglru_init", "rglru_apply", "rglru_decode", "init_rglru_state"]

C_FACTOR = 8.0


def rglru_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ [0.9, 0.999] at r = 1 (Griffin appendix).
    u = jax.random.uniform(ks[0], (w,), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * C_FACTOR)))  # softplus^-1
    return {
        "in_x": dense_init(ks[1], (d, w), dtype=dtype),
        "in_gate": dense_init(ks[2], (d, w), dtype=dtype),
        "conv_w": dense_init(ks[3], (cfg.rglru.conv_width, w), fan_in=cfg.rglru.conv_width, dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": dense_init(ks[4], (w, w), dtype=dtype),
        "ba": jnp.zeros((w,), dtype),
        "wx": dense_init(ks[5], (w, w), dtype=dtype),
        "bx": jnp.zeros((w,), dtype),
        "lambda": lam.astype(jnp.float32),
        "out": dense_init(jax.random.fold_in(key, 7), (w, d), fan_in=w, dtype=dtype),
    }


def _gates(params, x):
    """Per-step decay a_t and gated input. x: (..., W) bf16 -> fp32 terms."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["wa"].astype(jnp.float32) + params["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["wx"].astype(jnp.float32) + params["bx"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, gated


def rglru_apply(params, u, cfg: ModelConfig, *, return_state: bool = False):
    """Full-sequence recurrent block. u: (B, S, D) -> (B, S, D) [, state]."""
    dtype = u.dtype
    x = u @ params["in_x"].astype(dtype)
    x = maybe_constrain(x, "batch", "seq", "mlp")
    gate = jax.nn.gelu(u @ params["in_gate"].astype(dtype))
    x, conv_state = _causal_conv(
        x, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype)
    )
    a, gated = _gates(params, x)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(comb, (a, gated), axis=1)
    y = (h.astype(dtype)) * gate
    out = y @ params["out"].astype(dtype)
    if return_state:
        return out, {"h": h[:, -1], "conv": conv_state}
    return out


def init_rglru_state(cfg: ModelConfig, batch: int, dtype):
    w = cfg.rglru.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w), dtype),
    }


def rglru_decode(params, u, state, cfg: ModelConfig):
    """One token. u: (B, 1, D) -> (y, new_state)."""
    dtype = u.dtype
    x = u @ params["in_x"].astype(dtype)
    gate = jax.nn.gelu(u @ params["in_gate"].astype(dtype))
    x, conv_state = _causal_conv(
        x, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype),
        state=state["conv"],
    )
    a, gated = _gates(params, x)  # (B, 1, W)
    h = a[:, 0] * state["h"] + gated[:, 0]
    y = h[:, None, :].astype(dtype) * gate
    return y @ params["out"].astype(dtype), {"h": h, "conv": conv_state}
