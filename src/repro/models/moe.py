"""Mixture-of-Experts layer: shared + routed top-k, sort-based dispatch.

NXgraph-technique note (DESIGN.md §Arch-applicability): token→expert
dispatch is a bipartite graph update. We dispatch by *sorting the
(token, expert) assignments by expert id* — the exact analogue of the
paper's destination-sorted edges — so each expert's tokens are a
contiguous block and the per-expert matmul is a dense, conflict-free
"sub-shard update". Capacity-factor dropping bounds the block size the
way the paper's interval partitioning bounds sub-shard working sets.

Experts are padded to a multiple of 16 for EP divisibility (qwen2-moe:
60→64); dummy experts have zero weights and the router never emits them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init
from repro.sharding.rules import maybe_constrain

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    e_pad = m.num_experts_padded
    ks = jax.random.split(key, 5)
    p: dict = {
        "router": dense_init(ks[0], (d, m.num_experts), dtype=jnp.float32),
        # routed experts: fused [gate; up] then down, stacked on expert axis
        "wi": dense_init(ks[1], (e_pad, d, 2 * m.expert_ff), fan_in=d, dtype=dtype),
        "wo": dense_init(
            ks[2], (e_pad, m.expert_ff, d), fan_in=m.expert_ff, dtype=dtype
        ),
    }
    if m.num_experts != e_pad:
        # zero the dummy experts so padding is inert even if ever hit
        mask = (jnp.arange(e_pad) < m.num_experts).astype(dtype)
        p["wi"] = p["wi"] * mask[:, None, None]
        p["wo"] = p["wo"] * mask[:, None, None]
    if m.shared_ff:
        p["shared"] = mlp_init(ks[3], d, m.shared_ff, cfg.activation, dtype)
    return p


DENSE_PATH_MAX_TOKENS = 256  # below this, run the exact dropless path


def moe_apply(params, x, cfg: ModelConfig, *, return_aux: bool = True):
    """x: (B, S, D) -> (y, aux). aux carries the load-balancing loss.

    Two compute paths:
      * T > DENSE_PATH_MAX_TOKENS — sort-based capacity dispatch (training /
        long prefill; GShard-style, may drop overflow tokens).
      * T ≤ DENSE_PATH_MAX_TOKENS — dense all-experts einsum (decode / short
        prefill): exact and dropless, so prefill↔decode are consistent.
        At decode T the all-experts overcompute is cheaper than dispatch.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, e_pad, k = m.num_experts, m.num_experts_padded, m.top_k
    xf = x.reshape(t, d)
    dtype = x.dtype

    # Router in fp32 (standard practice: routing decisions are precision-
    # sensitive). Softmax over real experts only.
    logits = xf.astype(jnp.float32) @ params["router"]
    if m.router_softcap:
        logits = m.router_softcap * jnp.tanh(logits / m.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (T, k)

    if t <= DENSE_PATH_MAX_TOKENS:
        return _moe_dense_path(
            params, xf, cfg, probs, gate_vals, expert_ids, (b, s, d), return_aux
        )

    from repro.sharding.rules import active_mesh, active_rules

    mesh = active_mesh()
    rules = active_rules()
    if mesh is not None and rules.get("experts") == ():
        # FSDP/no-EP profile: dispatch must stay LOCAL per batch shard —
        # under pjit the data-dependent dispatch scatter gets fully
        # replicated (measured: 357 GB temp + 5.8 TB collectives on
        # deepseek train). shard_map makes per-shard locality explicit:
        # gather expert weights (the normal FSDP all-gather), route only
        # local tokens, zero MoE-specific collectives. This is the paper's
        # locality argument applied to the token->expert bipartite graph.
        return _moe_fsdp_local(params, x, cfg, mesh, rules, return_aux)

    # --- destination-sorted dispatch (the DSSS idea on the token-expert
    # bipartite graph): sort assignments by expert, slot into (E, C). ---
    cap = int(max(1, min(t, t * k * m.capacity_factor / e_pad)))
    flat_e = expert_ids.reshape(-1)  # (T·k,)
    order = jnp.argsort(flat_e)  # stable: preserves token order per expert
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e_pad))
    pos_in_e = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e_pad * cap)  # drop -> OOB
    token_of = order // k
    x_disp = jnp.zeros((e_pad * cap, d), dtype)
    x_disp = x_disp.at[slot].set(xf[token_of], mode="drop")
    x_disp = x_disp.reshape(e_pad, cap, d)
    x_disp = maybe_constrain(x_disp, "experts", None, None)

    # per-expert fused-gated MLP ("sub-shard update": dense block matmul)
    wi = params["wi"].astype(dtype)
    wo = params["wo"].astype(dtype)
    h = jnp.einsum("ecd,edf->ecf", x_disp, wi)
    h = maybe_constrain(h, "experts", None, None)
    gate, up = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    hh = act(gate) * up
    y_disp = jnp.einsum("ecf,efd->ecd", hh, wo).reshape(e_pad * cap, d)

    # combine: gather back and weight by gate values
    gathered = y_disp.at[slot].get(mode="fill", fill_value=0)  # (T·k, d)
    # gate_vals is token-major; index i here runs in SORTED order — permute
    # the gates through `order` or every token gets another token's gate
    # (regression-tested against the dense path in tests).
    w = (gate_vals.reshape(-1)[order] * keep).astype(dtype)
    contrib = gathered * w[:, None]
    y = jax.ops.segment_sum(contrib, token_of, num_segments=t).astype(dtype)

    if m.shared_ff:
        y = y + mlp_apply(params["shared"], xf, cfg.activation)
    y = y.reshape(b, s, d)

    aux = {}
    if return_aux:
        # GShard/Switch load-balance loss: E · Σ_e f_e · p_e.
        me = probs.mean(axis=0)  # (E,)
        one_hot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)
        ce = one_hot.sum(axis=(0, 1)) / (t * k)
        aux["load_balance_loss"] = e * jnp.sum(me * ce)
        aux["dropped_fraction"] = 1.0 - keep.mean()
    return y, aux


def _sorted_dispatch_compute(xf, probs, gate_vals, expert_ids, wi, wo, cfg):
    """Core destination-sorted dispatch + expert matmuls on LOCAL arrays.

    xf: (T, d); wi/wo: full (E_pad, ...) expert weights. Returns (y (T, d),
    dropped_fraction). Pure function of local data — used by both the pjit
    path (global arrays) and the shard_map FSDP path (per-shard arrays).
    """
    m = cfg.moe
    t, d = xf.shape
    e_pad, k = m.num_experts_padded, m.top_k
    dtype = xf.dtype
    cap = int(max(1, min(t, t * k * m.capacity_factor / e_pad)))
    flat_e = expert_ids.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e_pad))
    pos_in_e = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e_pad * cap)
    token_of = order // k
    x_disp = jnp.zeros((e_pad * cap, d), dtype)
    x_disp = x_disp.at[slot].set(xf[token_of], mode="drop")
    x_disp = x_disp.reshape(e_pad, cap, d)
    h = jnp.einsum("ecd,edf->ecf", x_disp, wi.astype(dtype))
    gate, up = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    hh = act(gate) * up
    y_disp = jnp.einsum("ecf,efd->ecd", hh, wo.astype(dtype)).reshape(
        e_pad * cap, d
    )
    gathered = y_disp.at[slot].get(mode="fill", fill_value=0)
    # token-major gates -> sorted order (see note in moe_apply)
    w = (gate_vals.reshape(-1)[order] * keep).astype(dtype)
    contrib = gathered * w[:, None]
    y = jax.ops.segment_sum(contrib, token_of, num_segments=t).astype(dtype)
    return y, 1.0 - keep.mean()


def _moe_fsdp_local(params, x, cfg: ModelConfig, mesh, rules, return_aux):
    """shard_map MoE for the FSDP/no-EP profile: local dispatch per shard."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import spec_for

    try:
        shard_map = jax.shard_map
    except AttributeError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    m = cfg.moe
    b, s, d = x.shape
    e = m.num_experts
    x_spec = spec_for(("batch", "seq", None), (b, s, d), mesh, rules)
    router_spec = spec_for(("embed", None), params["router"].shape, mesh, rules)
    wi_spec = spec_for(
        ("experts", "embed", "expert_mlp"), params["wi"].shape, mesh, rules
    )
    wo_spec = spec_for(
        ("experts", "expert_mlp", "embed"), params["wo"].shape, mesh, rules
    )
    has_shared = bool(m.shared_ff)
    if has_shared:
        swi_spec = spec_for(("embed", "mlp"), params["shared"]["wi"].shape, mesh, rules)
        swo_spec = spec_for(("mlp", "embed"), params["shared"]["wo"].shape, mesh, rules)
    all_axes = tuple(mesh.shape.keys())

    def _gather_full(w, spec):
        """Explicit FSDP all-gather of a weight shard (bf16 on the wire)."""
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for ax in reversed(axes):
                w = jax.lax.all_gather(w, ax, axis=dim, tiled=True)
        return w

    def body(xb, router, wi, wo, *shared):
        from repro.sharding.rules import suppress_constraints

        with suppress_constraints():
            return _body_inner(xb, router, wi, wo, *shared)

    def _body_inner(xb, router, wi, wo, *shared):
        bl, sl, _ = xb.shape
        xf = xb.reshape(bl * sl, d)
        router_f = _gather_full(router, router_spec).astype(jnp.float32)
        wi_f = _gather_full(wi.astype(xb.dtype), wi_spec)
        wo_f = _gather_full(wo.astype(xb.dtype), wo_spec)
        logits = xf.astype(jnp.float32) @ router_f
        if m.router_softcap:
            logits = m.router_softcap * jnp.tanh(logits / m.router_softcap)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
        y, dropped = _sorted_dispatch_compute(
            xf, probs, gate_vals, expert_ids, wi_f, wo_f, cfg
        )
        if has_shared:
            swi = _gather_full(shared[0].astype(xb.dtype), swi_spec)
            swo = _gather_full(shared[1].astype(xb.dtype), swo_spec)
            y = y + mlp_apply({"wi": swi, "wo": swo}, xf, cfg.activation)
        # aux scalars: psum over every axis -> replicated
        me = probs.mean(axis=0)
        oh = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)
        ce = oh.sum(axis=(0, 1)) / (xf.shape[0] * m.top_k)
        lbl = jax.lax.pmean(e * jnp.sum(me * ce), all_axes)
        dropped = jax.lax.pmean(dropped, all_axes)
        return y.reshape(bl, sl, d), lbl, dropped

    in_specs = [x_spec, router_spec, wi_spec, wo_spec]
    args = [x, params["router"], params["wi"], params["wo"]]
    if has_shared:
        in_specs += [swi_spec, swo_spec]
        args += [params["shared"]["wi"], params["shared"]["wo"]]
    y, lbl, dropped = shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(x_spec, P(), P()),
        check_vma=False,
    )(*args)
    aux = (
        {"load_balance_loss": lbl, "dropped_fraction": dropped}
        if return_aux
        else {}
    )
    return y, aux


def _moe_dense_path(params, xf, cfg, probs, gate_vals, expert_ids, bsd, return_aux):
    """Exact dropless path: every expert runs on every token, combined by the
    (sparse) top-k gate matrix. O(T·E·F) compute — only used for small T."""
    m = cfg.moe
    b, s, d = bsd
    t, e = probs.shape
    e_pad = m.num_experts_padded
    dtype = xf.dtype
    # (T, E_pad) combine weights: gate value where expert is in top-k, else 0.
    onehot = jax.nn.one_hot(expert_ids, e_pad, dtype=jnp.float32)  # (T,k,Ep)
    combine = jnp.einsum("tk,tke->te", gate_vals, onehot).astype(dtype)
    wi = params["wi"].astype(dtype)
    wo = params["wo"].astype(dtype)
    h = jnp.einsum("td,edf->tef", xf, wi)
    gate, up = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    hh = act(gate) * up
    y_e = jnp.einsum("tef,efd->ted", hh, wo)
    y = jnp.einsum("ted,te->td", y_e, combine)
    if m.shared_ff:
        y = y + mlp_apply(params["shared"], xf, cfg.activation)
    aux = {}
    if return_aux:
        me = probs.mean(axis=0)
        oh = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)
        ce = oh.sum(axis=(0, 1)) / (t * m.top_k)
        aux["load_balance_loss"] = e * jnp.sum(me * ce)
        aux["dropped_fraction"] = jnp.zeros((), jnp.float32)
    return y.reshape(b, s, d), aux
