"""Model definitions for the assigned architectures (pure-functional JAX)."""
from repro.models.model import Model, input_specs
from repro.models.transformer import (
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    prefill,
)

__all__ = [
    "Model",
    "input_specs",
    "forward",
    "prefill",
    "decode_step",
    "init_params",
    "init_decode_cache",
]
