"""Shared neural building blocks (pure-functional, no framework deps).

Params are nested dicts of jnp arrays; every builder has an ``init_*``
(returns params) and an ``apply``-style pure function. Compute runs in
``cfg.dtype`` (bf16 by default); norms and softmaxes in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import maybe_constrain

__all__ = [
    "dense_init",
    "rmsnorm_init",
    "rmsnorm",
    "rope",
    "mlp_init",
    "mlp_apply",
    "embed_init",
]


def dense_init(key, shape, fan_in: int | None = None, dtype=jnp.float32):
    """Truncated-normal with 1/sqrt(fan_in) scale (LeCun normal)."""
    if fan_in is None:
        fan_in = shape[0]
    std = fan_in**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d))).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding. x: (..., S, H, Dh), positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def mlp_init(key, d: int, ff: int, activation: str, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    if activation == "gelu_plain":  # whisper: 2-matrix GELU MLP
        return {
            "wi": dense_init(k1, (d, ff), dtype=dtype),
            "wo": dense_init(k2, (ff, d), fan_in=ff, dtype=dtype),
        }
    # gated (SwiGLU / GeGLU): fused [gate; up] in one matrix
    return {
        "wi": dense_init(k1, (d, 2 * ff), dtype=dtype),
        "wo": dense_init(k2, (ff, d), fan_in=ff, dtype=dtype),
    }


def mlp_apply(params, x, activation: str):
    dtype = x.dtype
    wi = params["wi"].astype(dtype)
    wo = params["wo"].astype(dtype)
    if activation == "gelu_plain":
        h = jax.nn.gelu(x @ wi, approximate=True)
        h = maybe_constrain(
            h, *(("batch", "seq", "mlp") if h.ndim == 3 else ("batch", "mlp"))
        )
        return h @ wo
    gate_up = x @ wi
    gate_up = maybe_constrain(
        gate_up, *(("batch", "seq", "mlp") if gate_up.ndim == 3 else ("batch", "mlp"))
    )
    gate, up = jnp.split(gate_up, 2, axis=-1)
    act = jax.nn.silu if activation == "silu" else lambda g: jax.nn.gelu(g, approximate=True)
    return (act(gate) * up) @ wo
