"""Transformer assembly: blocks, scan-over-layers, remat, enc-dec.

Compile-time strategy (DESIGN.md §7): the depth dimension is a
``lax.scan`` over stacked per-pattern-position parameter trees, so XLA
traces ONE pattern instance regardless of depth — required to keep the
32-cell × 2-mesh dry-run compile budget sane. Heterogeneous patterns
(gemma2 local/global pairs, recurrentgemma rec/rec/attn triples) scan over
whole pattern instances; leading dense layers (deepseek-moe) and trailing
partial patterns run unscanned.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (
    attn_apply,
    attn_decode,
    attn_init,
    init_kv_cache,
)
from repro.models.layers import (
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.sharding.rules import maybe_constrain

__all__ = [
    "init_params",
    "forward",
    "prefill",
    "decode_step",
    "init_decode_cache",
]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, kind: str, *, cross: bool = False, dense_ff: int | None = None):
    ks = jax.random.split(key, 8)
    d, pd = cfg.d_model, _pdtype(cfg)
    p: dict[str, Any] = {"ln1": rmsnorm_init(d, pd)}
    if kind in ("global", "local"):
        p["attn"] = attn_init(ks[0], cfg, dtype=pd)
    elif kind == "recurrent":
        p["rec"] = rglru_lib.rglru_init(ks[0], cfg, dtype=pd)
    elif kind == "ssm":
        p["ssm"] = ssm_lib.mamba_init(ks[0], cfg, dtype=pd)
        return p  # mamba block subsumes the MLP
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cfg.post_norm:
        p["ln1_post"] = rmsnorm_init(d, pd)
    if cross:
        p["lnx"] = rmsnorm_init(d, pd)
        p["cross"] = attn_init(ks[1], cfg, dtype=pd)
    p["ln2"] = rmsnorm_init(d, pd)
    if cfg.moe is not None and dense_ff is None:
        p["moe"] = moe_lib.moe_init(ks[2], cfg, dtype=pd)
    else:
        ff = dense_ff or cfg.d_ff
        p["mlp"] = mlp_init(ks[2], d, ff, cfg.activation, pd)
    if cfg.post_norm:
        p["ln2_post"] = rmsnorm_init(d, pd)
    return p


def apply_block(
    params,
    x,
    cfg: ModelConfig,
    kind: str,
    positions,
    *,
    causal: bool = True,
    enc_out=None,  # encoder output for cross-attention blocks
    dense_ff: int | None = None,
):
    """Full-sequence block application. Returns (x, aux_losses)."""
    aux = {}
    x = maybe_constrain(x, "batch", "seq", None)
    # Constraining the (bf16) norm outputs pins the partition boundary — and
    # therefore the backward dx all-reduce — at a bf16 tensor, instead of
    # letting XLA fuse the fp32 norm-convert below the collective
    # (measured: the dominant train collective was f32[B,S,D] ARs).
    h = maybe_constrain(rmsnorm(params["ln1"], x, cfg.norm_eps), "batch", "seq", None)
    if kind in ("global", "local"):
        h, _ = attn_apply(params["attn"], h, cfg, positions, kind=kind, causal=causal)
    elif kind == "recurrent":
        h = rglru_lib.rglru_apply(params["rec"], h, cfg)
    elif kind == "ssm":
        h = ssm_lib.mamba_apply(params["ssm"], h, cfg)
        return x + h, aux
    if cfg.post_norm:
        h = rmsnorm(params["ln1_post"], h, cfg.norm_eps)
    x = x + h
    if enc_out is not None and "cross" in params:
        h = rmsnorm(params["lnx"], x, cfg.norm_eps)
        dtype = h.dtype
        k = jnp.einsum("btd,dhk->bthk", enc_out, params["cross"]["wk"].astype(dtype))
        v = jnp.einsum("btd,dhk->bthk", enc_out, params["cross"]["wv"].astype(dtype))
        h, _ = attn_apply(
            params["cross"], h, cfg, positions, kind="global",
            causal=False, kv_override=(k, v), use_rope=False,
        )
        x = x + h
    h = maybe_constrain(rmsnorm(params["ln2"], x, cfg.norm_eps), "batch", "seq", None)
    if "moe" in params:
        h, moe_aux = moe_lib.moe_apply(params["moe"], h, cfg)
        aux.update(moe_aux)
    else:
        h = mlp_apply(params["mlp"], h, cfg.activation)
    if cfg.post_norm:
        h = rmsnorm(params["ln2_post"], h, cfg.norm_eps)
    return x + h, aux


# ---------------------------------------------------------------------------
# Parameter tree
# ---------------------------------------------------------------------------
def _plan(cfg: ModelConfig):
    """(pre_kinds, n_scanned_blocks, pattern, tail_kinds).

    pre = leading unscanned layers (deepseek-moe dense layer 0);
    tail = trailing partial pattern."""
    kinds = list(cfg.layer_kinds())
    n_pre = cfg.moe.first_dense_layers if cfg.moe else 0
    pre = kinds[:n_pre]
    rest = kinds[n_pre:]
    pat = cfg.pattern
    nb = len(rest) // len(pat)
    tail = tuple(rest[nb * len(pat) :])
    return tuple(pre), nb, pat, tail


def init_params(cfg: ModelConfig, key) -> dict:
    pd = _pdtype(cfg)
    pre, nb, pat, tail = _plan(cfg)
    keys = jax.random.split(key, 8)
    cross = cfg.is_enc_dec
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_padded, cfg.d_model, pd),
        "final_norm": rmsnorm_init(cfg.d_model, pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], cfg.vocab_padded, cfg.d_model, pd)
    params["pre"] = [
        init_block(
            jax.random.fold_in(keys[2], i), cfg, kind, cross=cross,
            dense_ff=(cfg.moe.first_dense_ff if cfg.moe else None),
        )
        for i, kind in enumerate(pre)
    ]
    # Scanned stacks: one stacked tree per pattern position.
    stacks = []
    for pos, kind in enumerate(pat):
        per_block = [
            init_block(
                jax.random.fold_in(keys[3], pos * 10_000 + b), cfg, kind, cross=cross
            )
            for b in range(nb)
        ]
        stacks.append(
            jax.tree.map(lambda *xs: jnp.stack(xs), *per_block)
            if nb > 0
            else None
        )
    params["blocks"] = stacks
    params["tail"] = [
        init_block(jax.random.fold_in(keys[4], i), cfg, kind, cross=cross)
        for i, kind in enumerate(tail)
    ]
    if cfg.is_enc_dec:
        enc_blocks = [
            init_block(jax.random.fold_in(keys[5], i), cfg, "global")
            for i in range(cfg.encdec.num_encoder_layers)
        ]
        params["encoder"] = {
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
            "final_norm": rmsnorm_init(cfg.d_model, pd),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (train / scoring)
# ---------------------------------------------------------------------------
def _remat_policy(cfg: ModelConfig):
    cp = jax.checkpoint_policies
    table = {
        "nothing_saveable": cp.nothing_saveable,
        "dots_saveable": cp.dots_saveable,
        "everything_saveable": cp.everything_saveable,
        "dots_with_no_batch_dims_saveable": cp.dots_with_no_batch_dims_saveable,
    }
    return table[cfg.remat_policy]


def _run_encoder(params, frames, cfg: ModelConfig):
    """Whisper encoder over stub frame embeddings (B, T, D)."""
    x = frames.astype(_dtype(cfg))
    t = x.shape[1]
    # sinusoidal positions (whisper uses these on the conv output)
    d = cfg.d_model
    pos = jnp.arange(t)[:, None]
    div = jnp.exp(-jnp.log(10_000.0) * jnp.arange(0, d, 2) / d)
    pe = jnp.zeros((t, d))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div)).at[:, 1::2].set(jnp.cos(pos * div))
    x = x + pe.astype(x.dtype)
    positions = jnp.arange(t)

    def enc_block(p, h):
        return apply_block(
            p, h, cfg, "global", positions, causal=False
        )[0]

    body = jax.checkpoint(enc_block, policy=_remat_policy(cfg))

    def step(h, p):
        return body(p, h), None

    x, _ = jax.lax.scan(step, x, params["encoder"]["blocks"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _embed_tokens(params, tokens, cfg: ModelConfig):
    x = params["embed"][tokens].astype(_dtype(cfg))
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return maybe_constrain(x, "batch", "seq", None)


def forward(
    cfg: ModelConfig,
    params,
    tokens,  # (B, S) int32
    *,
    patch_embeds=None,  # (B, Np, D) vlm stub
    frames=None,  # (B, T, D) audio stub
    return_hidden: bool = False,
) -> tuple[jax.Array, dict]:
    """Full-sequence forward. Returns (logits (B, S*, Vpad), aux).

    ``return_hidden=True`` returns the post-final-norm hidden states
    instead of logits — the training loss fuses the head projection into
    its chunked cross-entropy so the (B, S, V) fp32 logits tensor never
    materializes (see train/step.py)."""
    x = _embed_tokens(params, tokens, cfg)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    enc_out = _run_encoder(params, frames, cfg) if frames is not None else None
    s = x.shape[1]
    positions = jnp.arange(s)
    aux_total: dict[str, jax.Array] = {}

    def add_aux(aux):
        for k, v in aux.items():
            aux_total[k] = aux_total.get(k, 0.0) + v

    pre, nb, pat, tail = _plan(cfg)
    for i, kind in enumerate(pre):
        x, aux = apply_block(
            params["pre"][i], x, cfg, kind, positions, enc_out=enc_out,
            dense_ff=(cfg.moe.first_dense_ff if cfg.moe else None),
        )
        add_aux(aux)

    if nb > 0:
        def pattern_body(stacked_slice, h):
            auxes = {}
            for pos, kind in enumerate(pat):
                h, aux = apply_block(
                    stacked_slice[pos], h, cfg, kind, positions, enc_out=enc_out
                )
                for k2, v2 in aux.items():
                    auxes[k2] = auxes.get(k2, 0.0) + v2
            # fixed key order for scan ys
            return h, tuple(auxes[k2] for k2 in sorted(auxes))

        body = jax.checkpoint(pattern_body, policy=_remat_policy(cfg))

        def step(h, stacked_slice):
            h, aux_vals = body(stacked_slice, h)
            return h, aux_vals

        x, aux_stacked = jax.lax.scan(step, x, tuple(params["blocks"]))
        # reduce scanned aux losses
        sample_aux = {}
        if aux_stacked:
            # recover key order from one unscanned application is not
            # possible here; reconstruct from known aux keys
            keys = (
                ["dropped_fraction", "load_balance_loss"]
                if cfg.moe is not None
                else []
            )
            for k2, v2 in zip(sorted(keys), aux_stacked):
                sample_aux[k2] = jnp.sum(v2)
        add_aux(sample_aux)

    for i, kind in enumerate(tail):
        x, aux = apply_block(
            params["tail"][i], x, cfg, kind, positions, enc_out=enc_out
        )
        add_aux(aux)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux_total
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, aux_total


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------
def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype, cross: bool):
    c: dict[str, Any] = {}
    if kind in ("global", "local"):
        c["kv"] = init_kv_cache(cfg, batch, max_len, kind=kind, dtype=dtype)
    elif kind == "recurrent":
        c["rec"] = rglru_lib.init_rglru_state(cfg, batch, dtype)
    elif kind == "ssm":
        c["ssm"] = ssm_lib.init_mamba_state(cfg, batch, dtype)
    if cross:
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        t = cfg.encdec.encoder_frames
        c["cross_kv"] = {
            "k": jnp.zeros((batch, t, kv, hd), dtype),
            "v": jnp.zeros((batch, t, kv, hd), dtype),
        }
    return c


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Cache pytree matching the parameter layout (scanned stacks stacked)."""
    dtype = _dtype(cfg)
    pre, nb, pat, tail = _plan(cfg)
    cross = cfg.is_enc_dec
    cache: dict[str, Any] = {
        "pre": [
            _init_block_cache(cfg, kind, batch, max_len, dtype, cross)
            for kind in pre
        ],
        "tail": [
            _init_block_cache(cfg, kind, batch, max_len, dtype, cross)
            for kind in tail
        ],
    }
    stacks = []
    for pos, kind in enumerate(pat):
        per = [
            _init_block_cache(cfg, kind, batch, max_len, dtype, cross)
            for _ in range(nb)
        ]
        stacks.append(
            jax.tree.map(lambda *xs: jnp.stack(xs), *per) if nb else None
        )
    cache["blocks"] = stacks
    return cache


def decode_block(params, x, bcache, cfg: ModelConfig, kind: str, pos, *, cross: bool):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind in ("global", "local"):
        h, new_kv = attn_decode(params["attn"], h, bcache["kv"], cfg, pos, kind=kind)
        bcache = {**bcache, "kv": new_kv}
    elif kind == "recurrent":
        h, new_rec = rglru_lib.rglru_decode(params["rec"], h, bcache["rec"], cfg)
        bcache = {**bcache, "rec": new_rec}
    elif kind == "ssm":
        h, new_ssm = ssm_lib.mamba_decode(params["ssm"], h, bcache["ssm"], cfg)
        return x + h, {**bcache, "ssm": new_ssm}
    if cfg.post_norm:
        h = rmsnorm(params["ln1_post"], h, cfg.norm_eps)
    x = x + h
    if cross and "cross" in params:
        h = rmsnorm(params["lnx"], x, cfg.norm_eps)
        h, _ = attn_decode(
            params["cross"], h, bcache["cross_kv"], cfg, pos, cross=True
        )
        x = x + h
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if "moe" in params:
        h, _ = moe_lib.moe_apply(params["moe"], h, cfg, return_aux=False)
    else:
        h = mlp_apply(params["mlp"], h, cfg.activation)
    if cfg.post_norm:
        h = rmsnorm(params["ln2_post"], h, cfg.norm_eps)
    return x + h, bcache


def decode_step(
    cfg: ModelConfig,
    params,
    cache: dict,
    token,  # (B, 1) int32
    pos,  # scalar int32
):
    """One-token decode against the cache. Returns (logits (B, 1, V), cache)."""
    x = _embed_tokens(params, token, cfg)
    pre, nb, pat, tail = _plan(cfg)
    cross = cfg.is_enc_dec
    new_cache: dict[str, Any] = {"pre": [], "tail": [], "blocks": []}
    for i, kind in enumerate(pre):
        x, bc = decode_block(
            params["pre"][i], x, cache["pre"][i], cfg, kind, pos, cross=cross
        )
        new_cache["pre"].append(bc)
    if nb > 0:
        def step(h, slices):
            p_slice, c_slice = slices
            c_out = []
            for p, kind in enumerate(pat):
                h, bc = decode_block(
                    p_slice[p], h, c_slice[p], cfg, kind, pos, cross=cross
                )
                c_out.append(bc)
            return h, tuple(c_out)

        x, blocks_cache = jax.lax.scan(
            step, x, (tuple(params["blocks"]), tuple(cache["blocks"]))
        )
        new_cache["blocks"] = list(blocks_cache)
    for i, kind in enumerate(tail):
        x, bc = decode_block(
            params["tail"][i], x, cache["tail"][i], cfg, kind, pos, cross=cross
        )
        new_cache["tail"].append(bc)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, new_cache


def prefill(
    cfg: ModelConfig,
    params,
    tokens,  # (B, S)
    max_len: int,
    *,
    patch_embeds=None,
    frames=None,
):
    """Run the full prompt, building the decode cache. Returns
    (last_logits (B, 1, V), cache). Implemented as forward + per-layer cache
    capture via teacher-forced decode-compatible state construction."""
    b, s = tokens.shape
    cache = init_decode_cache(cfg, b, max_len)
    x = _embed_tokens(params, tokens, cfg)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        s = x.shape[1]
    enc_out = _run_encoder(params, frames, cfg) if frames is not None else None
    positions = jnp.arange(s)
    pre, nb, pat, tail = _plan(cfg)
    cross = cfg.is_enc_dec
    dtype = _dtype(cfg)

    def fill_block(pblock, bcache, h, kind):
        hn = rmsnorm(pblock["ln1"], h, cfg.norm_eps)
        if kind in ("global", "local"):
            hn, (k, v) = attn_apply(pblock["attn"], hn, cfg, positions, kind=kind)
            size = bcache["kv"]["k"].shape[1]
            if kind == "local" and s > size:
                k, v = k[:, -size:], v[:, -size:]
                # ring layout: position p lives at slot p % size
                roll = (s % size) if kind == "local" else 0
                k = jnp.roll(k, roll, axis=1)
                v = jnp.roll(v, roll, axis=1)
                newk = k.astype(dtype)
                newv = v.astype(dtype)
            else:
                newk = jax.lax.dynamic_update_slice(
                    bcache["kv"]["k"], k.astype(dtype), (0, 0, 0, 0)
                )
                newv = jax.lax.dynamic_update_slice(
                    bcache["kv"]["v"], v.astype(dtype), (0, 0, 0, 0)
                )
            bcache = {**bcache, "kv": {"k": newk, "v": newv}}
        elif kind == "recurrent":
            hn, rec_state = rglru_lib.rglru_apply(
                pblock["rec"], hn, cfg, return_state=True
            )
            bcache = {**bcache, "rec": rec_state}
        elif kind == "ssm":
            hn, ssm_state = ssm_lib.mamba_apply(
                pblock["ssm"], hn, cfg, return_state=True
            )
            bcache = {**bcache, "ssm": ssm_state}
            return h + hn, bcache
        if cfg.post_norm:
            hn = rmsnorm(pblock["ln1_post"], hn, cfg.norm_eps)
        h = h + hn
        if cross and "cross" in pblock:
            hx = rmsnorm(pblock["lnx"], h, cfg.norm_eps)
            kx = jnp.einsum("btd,dhk->bthk", enc_out, pblock["cross"]["wk"].astype(dtype))
            vx = jnp.einsum("btd,dhk->bthk", enc_out, pblock["cross"]["wv"].astype(dtype))
            hx, _ = attn_apply(
                pblock["cross"], hx, cfg, positions, kind="global",
                causal=False, kv_override=(kx, vx), use_rope=False,
            )
            h = h + hx
            bcache = {
                **bcache,
                "cross_kv": {"k": kx.astype(dtype), "v": vx.astype(dtype)},
            }
        hn = rmsnorm(pblock["ln2"], h, cfg.norm_eps)
        if "moe" in pblock:
            hn, _ = moe_lib.moe_apply(pblock["moe"], hn, cfg, return_aux=False)
        else:
            hn = mlp_apply(pblock["mlp"], hn, cfg.activation)
        if cfg.post_norm:
            hn = rmsnorm(pblock["ln2_post"], hn, cfg.norm_eps)
        return h + hn, bcache

    for i, kind in enumerate(pre):
        x, cache["pre"][i] = fill_block(params["pre"][i], cache["pre"][i], x, kind)
    if nb > 0:
        def step(h, slices):
            p_slice, c_slice = slices
            c_out = []
            for p, kind in enumerate(pat):
                h, bc = fill_block(p_slice[p], c_slice[p], h, kind)
                c_out.append(bc)
            return h, tuple(c_out)

        x, blocks_cache = jax.lax.scan(
            step, x, (tuple(params["blocks"]), tuple(cache["blocks"]))
        )
        cache["blocks"] = list(blocks_cache)
    for i, kind in enumerate(tail):
        x, cache["tail"][i] = fill_block(params["tail"][i], cache["tail"][i], x, kind)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, cache


