"""Model facade: one entry point per (arch × shape-kind).

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs for
every model input of that shape cell — the dry-run lowers against these
(never allocating). Modality frontends are STUBS per the assignment:
vlm supplies patch embeddings, audio supplies frame embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import (
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    prefill,
)

__all__ = ["Model", "input_specs"]


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every input of this (arch, shape)."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {
            "tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
        }
        if cfg.vision is not None:
            np_ = cfg.vision.num_patches
            specs["tokens"] = sds((b, s - np_), jnp.int32)
            specs["labels"] = sds((b, s - np_), jnp.int32)
            specs["patch_embeds"] = sds((b, np_, cfg.d_model), jnp.bfloat16)
        if cfg.is_enc_dec:
            specs["frames"] = sds(
                (b, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((b, s), jnp.int32)}
        if cfg.vision is not None:
            np_ = cfg.vision.num_patches
            specs["tokens"] = sds((b, s - np_), jnp.int32)
            specs["patch_embeds"] = sds((b, np_, cfg.d_model), jnp.bfloat16)
        if cfg.is_enc_dec:
            specs["frames"] = sds(
                (b, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16
            )
        return specs
    # decode: one new token against a cache of seq_len
    cache = jax.eval_shape(lambda: init_decode_cache(cfg, b, s))
    return {
        "token": sds((b, 1), jnp.int32),
        "pos": sds((), jnp.int32),
        "cache": cache,
    }


class Model:
    """Thin stateless facade over the functional transformer."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return init_params(self.cfg, key)

    def apply(self, params, tokens, **extra):
        return forward(self.cfg, params, tokens, **extra)

    def prefill(self, params, tokens, max_len: int, **extra):
        return prefill(self.cfg, params, tokens, max_len, **extra)

    def decode(self, params, cache, token, pos):
        return decode_step(self.cfg, params, cache, token, pos)

    def init_cache(self, batch: int, max_len: int):
        return init_decode_cache(self.cfg, batch, max_len)
