"""Attention layer: GQA/MQA, RoPE, local windows, softcap, caches.

Three compute paths, selected by shape:
  * ``plain``   — materialized masked softmax (short sequences).
  * ``chunked`` — pure-jnp flash (lax.scan over KV blocks with online
    softmax): bounded memory for 32k+ prefill; XLA-compilable on any
    backend. This is what the dry-run lowers.
  * ``pallas``  — the Pallas flash kernel (TPU target; interpret on CPU).
Decode (single query against a cache) is a dedicated einsum path.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rope
from repro.sharding.rules import maybe_constrain

__all__ = [
    "attn_init",
    "attn_apply",
    "attn_decode",
    "init_kv_cache",
    "chunked_attention",
]

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, d_model: int | None = None, dtype=jnp.float32):
    d = d_model or cfg.d_model
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype).reshape(d, h, hd),
        "wk": dense_init(ks[1], (d, kv * hd), dtype=dtype).reshape(d, kv, hd),
        "wv": dense_init(ks[2], (d, kv * hd), dtype=dtype).reshape(d, kv, hd),
        "wo": dense_init(ks[3], (h * hd, d), fan_in=h * hd, dtype=dtype).reshape(
            h, hd, d
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def _project_qkv(params, x, cfg: ModelConfig, positions, use_rope=True):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = maybe_constrain(q, "batch", "seq", "heads", "head_dim")
    k = maybe_constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = maybe_constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def chunked_attention(
    q,  # (B, S, H, Dh)
    k,  # (B, Sk, K, Dh)
    v,
    *,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
    chunk: int = 1024,
    q_offset: int = 0,
):
    """Flash-style attention in pure jnp: lax.scan over KV chunks.

    Memory per step is O(S·chunk) instead of O(S·Sk) — required for the
    32k/500k shapes to fit HBM in the dry-run. On TPU hardware this maps
    1:1 onto kernels/flash_attention.py.
    """
    b, s, h, dh = q.shape
    _, sk, hkv, _ = k.shape
    group = h // hkv
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,S,D)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,K,Sk,D)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    nchunk = -(-sk // chunk)
    sk_pad = nchunk * chunk
    if sk_pad != sk:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
    kc = kf.reshape(b, hkv, nchunk, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = vf.reshape(b, hkv, nchunk, chunk, dh).transpose(2, 0, 1, 3, 4)
    q_pos = q_offset + jnp.arange(s)

    @functools.partial(
        jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable
    )
    def step(carry, inp):
        m_prev, l_prev, acc = carry
        idx, kb, vb = inp  # kb: (B, K, chunk, D)
        kb = jnp.repeat(kb, group, axis=1)  # (B, H, chunk, D)
        vb = jnp.repeat(vb, group, axis=1)
        sco = jnp.einsum("bhsd,bhcd->bhsc", qf, kb)
        if softcap is not None:
            sco = softcap * jnp.tanh(sco / softcap)
        k_pos = idx * chunk + jnp.arange(chunk)
        mask = (k_pos < sk)[None, :]
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        sco = jnp.where(mask[None, None], sco, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(sco, axis=-1))
        dead = m_cur <= NEG_INF / 2
        alpha = jnp.where(dead, 1.0, jnp.exp(m_prev - m_cur))
        p = jnp.exp(sco - jnp.where(dead, 0.0, m_cur)[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum("bhsc,bhcd->bhsd", p, vb)
        return (m_cur, l_cur, acc), None

    init = (
        jnp.full((b, h, s), NEG_INF, jnp.float32),
        jnp.zeros((b, h, s), jnp.float32),
        jnp.zeros((b, h, s, dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.arange(nchunk), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, S, H, Dh)


def _plain_attention(q, k, v, *, causal, window, softcap, scale):
    b, s, h, dh = q.shape
    _, sk, hkv, _ = k.shape
    group = h // hkv
    kf = jnp.repeat(k, group, axis=2)
    vf = jnp.repeat(v, group, axis=2)
    sco = jnp.einsum(
        "bshd,bthd->bhst", q.astype(jnp.float32) * scale, kf.astype(jnp.float32)
    )
    if softcap is not None:
        sco = softcap * jnp.tanh(sco / softcap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    sco = jnp.where(mask[None, None], sco, NEG_INF)
    p = jax.nn.softmax(sco, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def attn_apply(
    params,
    x,  # (B, S, D)
    cfg: ModelConfig,
    positions,  # (S,) or (B, S)
    *,
    kind: str = "global",  # "global" | "local"
    causal: bool = True,
    kv_override: tuple | None = None,  # cross-attention: (k, v) precomputed
    use_rope: bool = True,
):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    window = cfg.window_size if kind == "local" else None
    scale = cfg.head_dim**-0.5
    if kv_override is not None:
        dtype = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(dtype)
        k, v = kv_override
    else:
        q, k, v = _project_qkv(params, x, cfg, positions, use_rope=use_rope)
    s, sk = q.shape[1], k.shape[1]
    chunk = cfg.attn_chunk or 1024
    if max(s, sk) > 2048 or cfg.attn_impl == "chunked":
        out = chunked_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_softcap, scale=scale, chunk=min(chunk, sk),
        )
    elif cfg.attn_impl == "pallas":
        from repro.kernels.ops import attention as kernel_attention

        out = kernel_attention(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=causal, window=window, softcap=cfg.attn_softcap,
            scale=scale, use_kernel=True,
        ).transpose(0, 2, 1, 3)
    else:
        out = _plain_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_softcap, scale=scale,
        )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    y = maybe_constrain(y, "batch", "seq", None)
    return y, (k, v)


# ---------------------------------------------------------------------------
# Decode path: single new token against a cache.
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *, kind: str, dtype):
    """Cache for one attention layer. Local layers use a ring buffer of the
    window size (O(window) memory — required for long_500k recurrentgemma)."""
    size = min(max_len, cfg.window_size) if kind == "local" else max_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
    }


def attn_decode(
    params,
    x,  # (B, 1, D)
    cache: dict,
    cfg: ModelConfig,
    pos,  # scalar int32: index of the new token
    *,
    kind: str = "global",
    cross: bool = False,
    cross_len: int | None = None,
    use_rope: bool = True,
):
    """One decode step. Returns (out, new_cache)."""
    dtype = x.dtype
    scale = cfg.head_dim**-0.5
    positions = jnp.full((x.shape[0], 1), pos)
    if cross:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(dtype)
        k, v = cache["k"], cache["v"]
        valid = jnp.arange(k.shape[1]) < (cross_len or k.shape[1])
        new_cache = cache
    else:
        q, k_new, v_new = _project_qkv(
            params, x, cfg, positions, use_rope=use_rope
        )
        size = cache["k"].shape[1]
        slot = pos % size if kind == "local" else pos
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0)
        )
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0)
        )
        new_cache = {"k": k, "v": v}
        if kind == "local":
            # Ring buffer: slot s holds the key of position
            # pos − ((pos − s) mod size); it is valid iff that position has
            # been written, i.e. its age does not exceed pos itself.
            age = jnp.mod(pos - jnp.arange(size), size)
            valid = age <= pos
        else:
            valid = jnp.arange(size) <= pos
    # GQA via grouped einsum — NOT jnp.repeat: repeating the kv-head axis
    # of a sequence-sharded cache forces GSPMD to all-gather the whole
    # cache (measured: 90 GB/token fp32 on gemma2 decode_32k). The grouped
    # contraction keeps the cache's (batch, seq) sharding intact and the
    # softmax over the sharded seq axis lowers to partial reductions
    # (flash-decoding style).
    group = cfg.num_heads // cfg.num_kv_heads
    b = q.shape[0]
    qg = q.reshape(b, 1, cfg.num_kv_heads, group, cfg.head_dim)
    sco = jnp.einsum(
        "bqhgd,bthd->bhgqt",
        qg.astype(jnp.float32) * scale,
        k.astype(jnp.float32),
    )  # (B, KV, G, 1, S)
    if cfg.attn_softcap is not None:
        sco = cfg.attn_softcap * jnp.tanh(sco / cfg.attn_softcap)
    sco = jnp.where(valid[None, None, None, None, :], sco, NEG_INF)
    m = jnp.max(sco, axis=-1, keepdims=True)
    p = jnp.exp(sco - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bhgqt,bthd->bqhgd", p, v.astype(jnp.float32)
    ).reshape(b, 1, cfg.num_heads, cfg.head_dim).astype(dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, new_cache
