"""Mamba-1 block (falcon-mamba): selective SSM with chunked scan.

Train/prefill uses a two-level scan — an outer ``lax.scan`` over sequence
chunks carrying the (B, E, N) state, with a parallel associative scan
inside each chunk. This bounds the state-expanded intermediate to
(B, Lc, E_local, N) per step, which is what makes the 500k-token shapes
compile inside HBM once E is sharded over the model axis (DESIGN.md §5).
Decode carries (conv_state, ssm_state) and is O(1) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.rules import maybe_constrain

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "init_mamba_state"]

CHUNK = 128


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    e = s.expand * cfg.d_model
    dtr = s.dt_rank or cfg.d_model // 16
    return e, dtr, s.d_state, s.d_conv


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    e, dtr, n, k = _dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A: A = -(1..N) per channel.
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (e, 1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(ks[5], (e,)) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )))  # softplus^-1 of dt in [1e-3, 1e-1]
    return {
        "in_proj": dense_init(ks[0], (d, 2 * e), dtype=dtype),
        "conv_w": dense_init(ks[1], (k, e), fan_in=k, dtype=dtype),
        "conv_b": jnp.zeros((e,), dtype),
        "x_proj": dense_init(ks[2], (e, dtr + 2 * n), fan_in=e, dtype=dtype),
        "dt_proj": dense_init(ks[3], (dtr, e), fan_in=dtr, dtype=dtype),
        "dt_bias": dt_bias.astype(dtype),
        "A_log": jnp.log(a_init).astype(jnp.float32),
        "D": jnp.ones((e,), dtype),
        "out_proj": dense_init(ks[4], (e, d), fan_in=e, dtype=dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv along seq. x: (B, S, E), w: (K, E).

    ``state``: (B, K-1, E) trailing inputs from the previous segment; when
    given, also returns the new state."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, E)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1) :] if k > 1 else pad
    return out, new_state


def _ssm_params(params, x, e, dtr, n):
    """Per-step SSM coefficients from the input. x: (..., E)."""
    dbc = x @ params["x_proj"].astype(x.dtype)  # (..., dtr+2N)
    dt, b, c = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        dt @ params["dt_proj"].astype(x.dtype)
        + params["dt_bias"].astype(x.dtype)
    )  # (..., E)
    a = -jnp.exp(params["A_log"])  # (E, N), fp32
    return dt.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32), a


def mamba_apply(params, u, cfg: ModelConfig, *, return_state: bool = False):
    """Full-sequence Mamba block. u: (B, S, D) -> (B, S, D) [, final state].

    ``return_state=True`` also returns the (conv, ssm) state after the last
    position — used by prefill; costs nothing extra since the chunked scan
    already carries it."""
    e, dtr, n, k = _dims(cfg)
    b_, s_, d_ = u.shape
    dtype = u.dtype
    xz = u @ params["in_proj"].astype(dtype)
    xz = maybe_constrain(xz, "batch", "seq", "mlp")
    x, z = jnp.split(xz, 2, axis=-1)  # (B, S, E)
    x, conv_state = _causal_conv(x, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype))
    x = jax.nn.silu(x)
    dt, bmat, cmat, a = _ssm_params(params, x, e, dtr, n)
    xf = x.astype(jnp.float32)

    # chunked selective scan
    nchunks = -(-s_ // CHUNK)
    pad = nchunks * CHUNK - s_
    def padded(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
    # Padded steps must be scan identities (decay 1, input 0) or they would
    # corrupt the carried state that return_state exposes.
    valid = (jnp.arange(nchunks * CHUNK) < s_).astype(jnp.float32)
    dt_c = padded(dt).reshape(b_, nchunks, CHUNK, e).transpose(1, 0, 2, 3)
    b_c = padded(bmat).reshape(b_, nchunks, CHUNK, n).transpose(1, 0, 2, 3)
    c_c = padded(cmat).reshape(b_, nchunks, CHUNK, n).transpose(1, 0, 2, 3)
    x_c = padded(xf).reshape(b_, nchunks, CHUNK, e).transpose(1, 0, 2, 3)
    v_c = valid.reshape(nchunks, 1, CHUNK, 1)

    def chunk_step(h0, inp):
        dt_k, b_k, c_k, x_k, v_k = inp  # (B, Lc, ...), v_k (1, Lc, 1)
        # discretize: decay (B,Lc,E,N), input term dt*B*x
        decay = jnp.exp(dt_k[..., None] * a)  # (B,Lc,E,N)
        decay = decay * v_k[..., None] + (1.0 - v_k[..., None])
        inp_t = dt_k[..., None] * b_k[:, :, None, :] * x_k[..., None]
        inp_t = inp_t * v_k[..., None]

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        acc_a, acc_b = jax.lax.associative_scan(comb, (decay, inp_t), axis=1)
        h = acc_a * h0[:, None] + acc_b  # (B,Lc,E,N), running state incl. h0
        y_k = jnp.einsum("blen,bln->ble", h, c_k)
        return h[:, -1], y_k

    h0 = jnp.zeros((b_, e, n), jnp.float32)
    h_final, y = jax.lax.scan(chunk_step, h0, (dt_c, b_c, c_c, x_c, v_c))
    y = y.transpose(1, 0, 2, 3).reshape(b_, nchunks * CHUNK, e)[:, :s_]
    y = y + xf * params["D"]
    y = (y.astype(dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dtype)
    if return_state:
        return out, {"conv": conv_state, "ssm": h_final}
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    e, dtr, n, k = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, k - 1, e), dtype),
        "ssm": jnp.zeros((batch, e, n), jnp.float32),
    }


def mamba_decode(params, u, state, cfg: ModelConfig):
    """One token. u: (B, 1, D). Returns (y, new_state) — O(1) memory."""
    e, dtr, n, k = _dims(cfg)
    dtype = u.dtype
    xz = u @ params["in_proj"].astype(dtype)
    x, z = jnp.split(xz, 2, axis=-1)
    x, conv_state = _causal_conv(
        x, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype),
        state=state["conv"],
    )
    x = jax.nn.silu(x)
    dt, bmat, cmat, a = _ssm_params(params, x, e, dtr, n)
    xf = x.astype(jnp.float32)
    decay = jnp.exp(dt[:, 0, :, None] * a)  # (B,E,N)
    h = decay * state["ssm"] + dt[:, 0, :, None] * bmat[:, 0, None, :] * xf[:, 0, :, None]
    y = jnp.einsum("ben,bn->be", h, cmat[:, 0])[:, None, :] + xf * params["D"]
    y = y.astype(dtype) * jax.nn.silu(z)
    return y @ params["out_proj"].astype(dtype), {"conv": conv_state, "ssm": h}
